"""Batched vs sequential MHQ serving throughput (QPS at equal recall).

The sequential baseline is the per-query loop every layer used before the
batched subsystem existed: optimize + execute + host sync, one query at a
time. The batched path is ``ServingEngine`` -> ``BoomHQ.execute_batch``:
one fused vmapped optimizer dispatch per batch plus grouped vmapped
execution. Per-query results match up to float reduction order
(tests/test_batch.py asserts tie-tolerant parity), so the recall columns
must match and the QPS column is pure dispatch/batching win.

  PYTHONPATH=src python -m benchmarks.serving            # FAST suite
  PYTHONPATH=src python -m benchmarks.serving --smoke    # tiny, seconds
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from benchmarks import common
from repro.bench import queries
from repro.core.executor import recall_at_k
from repro.serve.batch import ServingEngine

SMOKE = dict(common.FAST, rows=4000, n_train=16, n_test=8, frozen_steps=25,
             ae_steps=40, rw_steps=100, n_clusters=16)


def run(sizes=common.FAST, dataset: str = "part", *, n_stream: int = 64,
        batch_size: int = 32, seed: int = 0) -> dict:
    suite = common.build_suite(dataset, n_vec_used=2, seed=seed, sizes=sizes)
    bq = suite.bq

    # a serving stream larger than the test split, same generator settings
    stream = queries.gen_workload(suite.table, n_stream, n_vec_used=2,
                                  seed=seed + 100)
    gts = [common.flat.ground_truth(suite.table, list(q.query_vectors),
                                    list(q.weights), q.predicates, q.k)[0]
           for q in stream]
    gts = [np.asarray(g) for g in gts]

    engine = ServingEngine(bq, batch_size=batch_size)
    # steady-state measurement: ONE untimed pass per path populates every
    # jit specialization (a long-running service reuses a bounded kernel
    # cache; cold-compile cost is amortized away in both columns)
    engine.serve(stream)
    for q in stream:
        bq.execute(q)

    # -- sequential per-query loop (the pre-batching serving path) ---------
    seq_recs = []
    t0 = time.perf_counter()
    for q, gt in zip(stream, gts):
        ids, _ = bq.execute(q)
        seq_recs.append(recall_at_k(ids, gt))
    seq_s = time.perf_counter() - t0
    seq_qps = len(stream) / seq_s

    # -- batched ----------------------------------------------------------
    _, rep = engine.serve(stream, gt_ids=gts)

    speedup = rep.qps / seq_qps
    out = {
        "figure": "serving_batched_vs_sequential",
        "dataset": dataset, "rows": suite.table.n_rows,
        "n_stream": n_stream, "batch_size": batch_size,
        "sequential_qps": round(seq_qps, 1),
        "sequential_recall": round(float(np.mean(seq_recs)), 3),
        "batched_qps": round(rep.qps, 1),
        "batched_recall": round(rep.mean_recall, 3),
        "batched_speedup": round(speedup, 2),
    }
    print(f"  serving {dataset}: sequential {seq_qps:.1f} QPS "
          f"(recall {np.mean(seq_recs):.3f}) vs batched {rep.qps:.1f} QPS "
          f"(recall {rep.mean_recall:.3f}) -> {speedup:.2f}x")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="part")
    ap.add_argument("--n-stream", type=int, default=64)
    ap.add_argument("--batch-size", type=int, default=32)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny table for a seconds-long sanity run")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    sizes = SMOKE if args.smoke else (common.FULL if args.full else common.FAST)
    res = run(sizes, args.dataset, n_stream=args.n_stream,
              batch_size=args.batch_size)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(res, f, indent=2)


if __name__ == "__main__":
    main()
