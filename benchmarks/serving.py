"""MHQ serving throughput: batched vs sequential, and async over shards.

Two measurements on one fitted suite:

  * ``run_sync_compare`` — the original figure: the sequential per-query
    loop vs ``ServingEngine`` -> ``BoomHQ.execute_batch`` (one fused
    optimizer dispatch + grouped vmapped execution per batch). Per-query
    results match up to float reduction order, so the recall columns must
    match and the QPS column is pure dispatch/batching win.
  * ``run_async_shards`` — the live-traffic figure: Poisson (open-loop)
    arrivals into the deadline-aware ``AsyncServingEngine``, served over
    1 / 2 / 4 table shards. The single-shard row is the plan-driven batched
    path; multi-shard rows fan every formed batch out across the shards
    (per-shard mask + local top-k on the dense score matrices, one
    O(shards·k) merge). Reports QPS, p50/p99 latency, timed-out count
    (zero at the default deadline) and oracle recall per shard count.

  PYTHONPATH=src python -m benchmarks.serving            # FAST suite
  PYTHONPATH=src python -m benchmarks.serving --smoke    # tiny, seconds

Run as a script the process forces 4 host devices, so the 2/4-shard rows
execute under shard_map on a real device mesh; under ``benchmarks.run``
(single-device process) they use logical shards with identical semantics.
"""
from __future__ import annotations

import argparse
import asyncio
import json
import os
import time

DEFAULT_SHARDS = (1, 2, 4)
DEFAULT_DEADLINE = 5.0  # seconds — generous; the report must show 0 timeouts
DEFAULT_RATE = 100.0  # Poisson arrivals per second


def _smoke_sizes():
    from benchmarks import common

    return dict(common.FAST, rows=4000, n_train=16, n_test=8, frozen_steps=25,
                ae_steps=40, rw_steps=100, n_clusters=16)


def _stream_and_gts(suite, n_stream: int, seed: int):
    import numpy as np

    from benchmarks import common
    from repro.bench import queries

    stream = queries.gen_workload(suite.table, n_stream, n_vec_used=2,
                                  seed=seed + 100)
    gts = [np.asarray(common.flat.ground_truth(
        suite.table, list(q.query_vectors), list(q.weights), q.predicates,
        q.k)[0]) for q in stream]
    return stream, gts


def run_sync_compare(suite, stream, gts, *, batch_size: int = 32) -> dict:
    """Sequential per-query loop vs the batched ServingEngine."""
    import numpy as np

    from repro.core.executor import recall_at_k
    from repro.serve.batch import ServingEngine

    bq = suite.bq
    engine = ServingEngine(bq, batch_size=batch_size)
    # steady-state measurement: ONE untimed pass per path populates every
    # jit specialization (a long-running service reuses a bounded kernel
    # cache; cold-compile cost is amortized away in both columns)
    engine.serve(stream)
    for q in stream:
        bq.execute(q)

    seq_recs = []
    t0 = time.perf_counter()
    for q, gt in zip(stream, gts):
        ids, _ = bq.execute(q)
        seq_recs.append(recall_at_k(ids, gt))
    seq_s = time.perf_counter() - t0
    seq_qps = len(stream) / seq_s

    _, rep = engine.serve(stream, gt_ids=gts)
    speedup = rep.qps / seq_qps
    print(f"  serving sync: sequential {seq_qps:.1f} QPS "
          f"(recall {np.mean(seq_recs):.3f}) vs batched {rep.qps:.1f} QPS "
          f"(recall {rep.mean_recall:.3f}) -> {speedup:.2f}x")
    return {
        "sequential_qps": round(seq_qps, 1),
        "sequential_recall": round(float(np.mean(seq_recs)), 3),
        "batched_qps": round(rep.qps, 1),
        "batched_recall": round(rep.mean_recall, 3),
        "batched_speedup": round(speedup, 2),
    }


def run_async_shards(suite, stream, gts, *, batch_size: int = 32,
                     shards=DEFAULT_SHARDS, rate: float = DEFAULT_RATE,
                     max_wait: float = 0.01,
                     deadline: float = DEFAULT_DEADLINE, seed: int = 0
                     ) -> list[dict]:
    """Poisson open-loop arrivals into AsyncServingEngine per shard count."""
    import numpy as np

    import jax

    from repro.serve.batch import warm_bucket_ladder
    from repro.serve.queue import AsyncServingEngine, serve_stream

    bq = suite.bq
    rng = np.random.default_rng(seed + 7)
    gaps = rng.exponential(1.0 / rate, len(stream) - 1).tolist()
    rows = []
    try:
        for s in shards:
            mesh = None
            if s > 1:
                if jax.device_count() >= s and suite.table.n_rows % s == 0:
                    from jax.sharding import Mesh
                    mesh = Mesh(np.array(jax.devices()[:s]), ("data",))
                    bq.bind_shards(mesh=mesh)
                else:
                    bq.bind_shards(s)  # logical shards, same semantics
            else:
                bq.bind_shards()  # plan-driven single-shard baseline
            warm_bucket_ladder(bq.execute_batch, stream, batch_size)
            engine = AsyncServingEngine(bq, batch_size=batch_size,
                                        max_wait=max_wait,
                                        default_timeout=deadline)
            reqs = asyncio.run(serve_stream(engine, stream,
                                            arrival_gaps=gaps))
            rep = engine.report(
                gt_ids={r.seq: gts[i] for i, r in enumerate(reqs)})
            row = {
                "shards": s,
                "mesh": mesh is not None,
                "qps": round(rep.qps, 1),
                "p50_ms": round(rep.p50_ms, 2),
                "p99_ms": round(rep.p99_ms, 2),
                "timed_out": rep.n_timed_out,
                "recall": round(rep.mean_recall, 3),
            }
            rows.append(row)
            print(f"  serving async shards={s}{' (mesh)' if row['mesh'] else ''}: "
                  f"{row['qps']} QPS, p50 {row['p50_ms']}ms, "
                  f"p99 {row['p99_ms']}ms, {row['timed_out']} timed out, "
                  f"recall {row['recall']}")
    finally:
        bq.bind_shards()  # leave the suite single-shard
    return rows


def run_sharded(dataset: str = "sift", rows: int = 500_000, shards: int = 4,
                *, batch_size: int = 32, n_stream: int = 64,
                max_scan: int = 2048, nprobe: int = 16, k_mult: int = 4,
                k: int = 10, seed: int = 0, use_mesh: bool = False) -> dict:
    """Sharded-IVF acceptance sweep: the plan's knobs operative at shard
    scale.

    Apples-to-apples at the plan tier where learned plans put large tables
    (index_scan at the smallest ``MAX_SCAN_GRID`` budget — the same regime
    ``run_crossover`` measures): the SAME legalized plan drives

      * ``1shard`` — the single-device batched executor, i.e. the existing
        single-device results (the 1-shard sharded configuration is
        bit-for-bit this path — tests/test_sharded_ivf.py);
      * ``{S}shard-dense-exact`` — the exact per-shard scan over the dense
        score matrices (the PR 3 fan-out; plans ignored, recall 1.0 by
        construction);
      * ``{S}shard-ivf`` — plan-driven per-shard IVF probing: each shard
        probes its own index with the shard-legalized knobs, reranks
        candidate-locally inside the shard, one O(shards·k) merge, and a
        query whose merged result underfills k takes the exact retry over
        only its underfilled shard-subset (the recall contract).

    The stratified stream deliberately includes the paper's HARD stratum —
    correlated predicates that empty out the probed neighborhoods (this
    repo's v→s scalars are derived from vector geometry), where the exact
    scan is genuinely optimal and the probing path must pay the escalation
    tax to keep its recall contract. The sweep therefore reports the full
    stream AND the probe-served tier (the queries whose probes filled k —
    the tier a fitted optimizer routes here): acceptance is that the
    probing path beats the exact sharded dense scan in QPS on that tier at
    an oracle recall no lower than the single-shard plan-driven path, with
    the full-stream recall also no lower (escalation only adds rows).

    QPS rows use LOGICAL shards by default: this is a single-host
    container, and a forced host-platform mesh splits one physical CPU
    into fake devices — shard_map partitioning overhead without real
    parallelism (measured: it halves every sharded row). The shard_map
    execution path is bit-parity-verified against the logical reference
    in tests/test_sharded_ivf.py and tests/test_distributed.py;
    ``use_mesh=True`` (CLI ``--mesh``) forces the mesh anyway."""
    import numpy as np

    import jax

    from repro.bench import datasets, queries
    from repro.core.executor import recall_at_k
    from repro.core.query import ExecutionPlan, SubqueryParams
    from repro.serve.batch import (
        SHARDED_LOCAL, BatchedHybridExecutor, CostModel,
    )
    from repro.vectordb import flat, ivf

    table = datasets.make(dataset, rows=rows, seed=seed)
    n_vec = table.schema.n_vec
    nc = max(64, min(512, table.n_rows // 2000))
    t0 = time.time()
    idx = [ivf.build(v, nc, seed=i, metric=table.schema.metric)
           for i, v in enumerate(table.vectors)]
    print(f"  sharded suite built in {time.time() - t0:.0f}s "
          f"({table.n_rows} rows, {nc} clusters)")
    stream = queries.gen_workload(table, n_stream,
                                  n_vec_used=min(2, n_vec), seed=seed + 100)
    gts = [np.asarray(flat.ground_truth(
        table, list(q.query_vectors), list(q.weights), q.predicates,
        q.k)[0]) for q in stream]
    plan = ExecutionPlan("index_scan", tuple(
        SubqueryParams(k_mult=k_mult, nprobe=nprobe, max_scan=max_scan,
                       iterative=True) for _ in range(n_vec)))

    mesh = None
    if use_mesh and jax.device_count() >= shards \
            and table.n_rows % shards == 0:
        from jax.sharding import Mesh
        mesh = Mesh(np.array(jax.devices()[:shards]), ("data",))

    def make_bx(s, cm=None):
        kw = {} if s <= 1 else (
            {"mesh": mesh} if mesh is not None else {"n_shards": s})
        return BatchedHybridExecutor(table, idx, cost_model=cm, **kw)

    def serve(bx, mode, qs, q_gts, esc_out=None):
        plans = [plan] * len(qs)

        def call(sub, ps):
            if mode == "execute_batch":
                return bx.execute_batch(sub, ps)
            if mode == "sharded_no_plans":
                return bx.execute_batch_sharded(sub)
            return bx.execute_batch_sharded(sub, ps)

        call(qs[:batch_size], plans[:batch_size])  # warm the jit caches
        t0 = time.perf_counter()
        results = []
        for i in range(0, len(qs), batch_size):
            bx.escalated.clear()  # batch-relative indices
            results.extend(call(qs[i: i + batch_size],
                                plans[i: i + batch_size]))
            if esc_out is not None:
                esc_out.update(i + j for j in bx.escalated)
        dt = time.perf_counter() - t0
        recs = [recall_at_k(ids, gt)
                for (ids, _), gt in zip(results, q_gts)]
        return {"qps": round(len(qs) / dt, 1),
                "recall": round(float(np.mean(recs)), 3)}

    bx1 = make_bx(1)
    bxd = make_bx(shards)
    bxi = make_bx(shards, CostModel(force=SHARDED_LOCAL))
    rows_out = []
    esc = set()  # filled by the sharded-ivf timed pass itself
    for label, bx, mode in (
            ("1shard", bx1, "execute_batch"),
            (f"{shards}shard-dense-exact", bxd, "sharded_no_plans"),
            (f"{shards}shard-ivf", bxi, "sharded_plans")):
        row = {"config": label, "stream": "full",
               "mesh": bx is not bx1 and mesh is not None,
               **serve(bx, mode, stream, gts,
                       esc_out=esc if bx is bxi else None)}
        rows_out.append(row)
        print(f"  sharded {label}{' (mesh)' if row['mesh'] else ''}: "
              f"{row['qps']} QPS, recall {row['recall']}")
    # escalation segmentation: the probe-served tier re-measured alone
    served = [j for j in range(len(stream)) if j not in esc]
    out_tier = {}
    if served:
        sub = [stream[j] for j in served]
        sub_gts = [gts[j] for j in served]
        for label, bx, mode in (
                ("1shard", bx1, "execute_batch"),
                (f"{shards}shard-dense-exact", bxd, "sharded_no_plans"),
                (f"{shards}shard-ivf", bxi, "sharded_plans")):
            row = {"config": label, "stream": "probe-served",
                   "mesh": bx is not bx1 and mesh is not None,
                   **serve(bx, mode, sub, sub_gts)}
            rows_out.append(row)
            print(f"  probe-served tier {label}: {row['qps']} QPS, "
                  f"recall {row['recall']}")
        by_tier = {r["config"]: r for r in rows_out
                   if r["stream"] == "probe-served"}
        out_tier = {
            "probe_served_queries": len(served),
            "ivf_vs_dense_speedup_probe_served": round(
                by_tier[f"{shards}shard-ivf"]["qps"]
                / by_tier[f"{shards}shard-dense-exact"]["qps"], 2),
            "tier_recall_delta_vs_single": round(
                by_tier[f"{shards}shard-ivf"]["recall"]
                - by_tier["1shard"]["recall"], 4),
        }
    by = {r["config"]: r for r in rows_out if r["stream"] == "full"}
    out = {
        "figure": "serving_sharded_ivf",
        "dataset": dataset, "rows": table.n_rows, "shards": shards,
        "batch_size": batch_size, "n_stream": n_stream,
        "plan": {"strategy": "index_scan", "k_mult": k_mult,
                 "nprobe": nprobe, "max_scan": max_scan},
        "table": rows_out,
        "escalated_queries": len(esc),
        "recall_delta_vs_single": round(
            by[f"{shards}shard-ivf"]["recall"] - by["1shard"]["recall"], 4),
        **out_tier,
    }
    print(f"  acceptance: full-stream recall delta vs 1shard "
          f"{out['recall_delta_vs_single']:+.3f} "
          f"({len(esc)}/{len(stream)} escalated); probe-served tier "
          f"speedup vs exact dense "
          f"{out.get('ivf_vs_dense_speedup_probe_served', 'n/a')}x at "
          f"recall delta {out.get('tier_recall_delta_vs_single', 'n/a')}")
    return out


# dense-vs-candidate-local acceptance sweep: (dataset, rows, batch sizes).
# part = 2×768-dim columns (the multi-vector MHQ shape); sift = 1×128-dim at
# half a million rows (the scale where the dense GEMM becomes the wall).
CROSSOVER_TABLES = (("part", 60_000, (8, 32)), ("sift", 500_000, (8, 32)))


def run_crossover(tables=CROSSOVER_TABLES, *, n_stream: int = 64,
                  max_scan: int = 2048, nprobe: int = 16, k_mult: int = 4,
                  seed: int = 0) -> list[dict]:
    """Dense vs candidate-local batched executor QPS at a fixed plan.

    Both paths run the SAME legalized plan (index_scan, the smallest
    ``MAX_SCAN_GRID`` budget — the regime learned plans put large tables
    in), so they probe identical candidate slots and their oracle recall
    must agree to float ties; the QPS difference is purely the scoring
    path. The executor is driven directly (fixed plans, no optimizer) so
    the table isolates scoring; ``auto_path`` reports what the calibrated
    ``CostModel`` would pick for each group."""
    import numpy as np

    from repro.bench import datasets, queries
    from repro.core.executor import recall_at_k
    from repro.core.query import ExecutionPlan, SubqueryParams
    from repro.serve.batch import (
        BatchedHybridExecutor, CANDIDATE_LOCAL, DENSE, CostModel, next_bucket,
    )
    from repro.vectordb import flat, ivf

    rows_out = []
    for dataset, rows, batch_sizes in tables:
        table = datasets.make(dataset, rows=rows, seed=seed)
        n_vec = table.schema.n_vec
        nc = max(64, min(512, table.n_rows // 2000))
        idx = [ivf.build(v, nc, seed=i, metric=table.schema.metric)
               for i, v in enumerate(table.vectors)]
        stream = queries.gen_workload(table, n_stream,
                                      n_vec_used=min(2, n_vec),
                                      seed=seed + 100)
        gts = [np.asarray(flat.ground_truth(
            table, list(q.query_vectors), list(q.weights), q.predicates,
            q.k)[0]) for q in stream]
        plan = ExecutionPlan("index_scan", tuple(
            SubqueryParams(k_mult=k_mult, nprobe=nprobe, max_scan=max_scan,
                           iterative=True) for _ in range(n_vec)))
        plans = [plan] * len(stream)
        for bs in batch_sizes:
            row = {"dataset": dataset, "rows": table.n_rows, "batch": bs,
                   "max_scan": max_scan}
            scan_budget = max_scan * len([w for w in stream[0].weights
                                          if w > 0])
            row["auto_path"] = CostModel().choose(
                batch=next_bucket(bs), scan=scan_budget, n_rows=table.n_rows)
            for label, force in (("dense", DENSE),
                                 ("local", CANDIDATE_LOCAL)):
                bx = BatchedHybridExecutor(
                    table, idx, cost_model=CostModel(force=force))
                bx.execute_batch(stream[:bs], plans[:bs])  # warm jit
                t0 = time.perf_counter()
                results = []
                for s in range(0, len(stream), bs):
                    results.extend(
                        bx.execute_batch(stream[s: s + bs],
                                         plans[s: s + bs]))
                dt = time.perf_counter() - t0
                row[f"{label}_qps"] = round(len(stream) / dt, 1)
                row[f"{label}_recall"] = round(float(np.mean(
                    [recall_at_k(ids, gt)
                     for (ids, _), gt in zip(results, gts)])), 3)
            row["speedup"] = round(row["local_qps"] / row["dense_qps"], 2)
            row["recall_delta"] = round(
                abs(row["local_recall"] - row["dense_recall"]), 4)
            rows_out.append(row)
            print(f"  crossover {dataset} rows={row['rows']} B={bs}: "
                  f"dense {row['dense_qps']} QPS (recall "
                  f"{row['dense_recall']}) vs candidate-local "
                  f"{row['local_qps']} QPS (recall {row['local_recall']}) "
                  f"-> {row['speedup']}x, auto={row['auto_path']}")
    return rows_out


def run_quantized(tables=CROSSOVER_TABLES, *, n_stream: int = 64,
                  max_scan: int = 2048, nprobe: int = 16, k_mult: int = 4,
                  seed: int = 0) -> list[dict]:
    """int8-then-rerank vs fp32 candidate-local QPS at a fixed plan.

    Both paths are the SAME candidate-local executor on the SAME legalized
    plan — identical probed slots, identical predicate filtering on exact
    scalars — differing ONLY in ``ExecutionPlan.precision``: fp32 scores
    the gathered candidates exactly; int8 scores them from the quantized
    replica and exact-reranks the top-α·k (docs/quantized_tier.md). The
    acceptance claim is the int8 column's QPS win at an oracle recall
    delta within 0.01: quantization only perturbs WHICH near-boundary
    candidates reach the exact rerank, never the returned scores.
    ``auto_path`` columns report what the calibrated per-precision
    ``CostModel`` crossover picks for each configuration."""
    import numpy as np

    from repro.bench import datasets, queries
    from repro.core.executor import recall_at_k
    from repro.core.query import ExecutionPlan, SubqueryParams
    from repro.serve.batch import (
        BatchedHybridExecutor, CANDIDATE_LOCAL, CostModel, next_bucket,
    )
    from repro.vectordb import flat, ivf

    rows_out = []
    for dataset, rows, batch_sizes in tables:
        table = datasets.make(dataset, rows=rows, seed=seed)
        n_vec = table.schema.n_vec
        nc = max(64, min(512, table.n_rows // 2000))
        idx = [ivf.build(v, nc, seed=i, metric=table.schema.metric)
               for i, v in enumerate(table.vectors)]
        stream = queries.gen_workload(table, n_stream,
                                      n_vec_used=min(2, n_vec),
                                      seed=seed + 100)
        gts = [np.asarray(flat.ground_truth(
            table, list(q.query_vectors), list(q.weights), q.predicates,
            q.k)[0]) for q in stream]
        for bs in batch_sizes:
            row = {"dataset": dataset, "rows": table.n_rows, "batch": bs,
                   "max_scan": max_scan}
            scan_budget = max_scan * len([w for w in stream[0].weights
                                          if w > 0])
            for prec in ("fp32", "int8"):
                plan = ExecutionPlan("index_scan", tuple(
                    SubqueryParams(k_mult=k_mult, nprobe=nprobe,
                                   max_scan=max_scan, iterative=True)
                    for _ in range(n_vec)), precision=prec)
                plans = [plan] * len(stream)
                row[f"auto_path_{prec}"] = CostModel().choose(
                    batch=next_bucket(bs), scan=scan_budget,
                    n_rows=table.n_rows, precision=prec)
                bx = BatchedHybridExecutor(
                    table, idx,
                    cost_model=CostModel(force=CANDIDATE_LOCAL))
                bx.execute_batch(stream[:bs], plans[:bs])  # warm jit
                t0 = time.perf_counter()
                results = []
                for s in range(0, len(stream), bs):
                    results.extend(
                        bx.execute_batch(stream[s: s + bs],
                                         plans[s: s + bs]))
                dt = time.perf_counter() - t0
                row[f"{prec}_qps"] = round(len(stream) / dt, 1)
                row[f"{prec}_recall"] = round(float(np.mean(
                    [recall_at_k(ids, gt)
                     for (ids, _), gt in zip(results, gts)])), 3)
            row["int8_speedup"] = round(
                row["int8_qps"] / row["fp32_qps"], 2)
            row["recall_delta"] = round(
                row["fp32_recall"] - row["int8_recall"], 4)
            rows_out.append(row)
            print(f"  quantized {dataset} rows={row['rows']} B={bs}: "
                  f"fp32-local {row['fp32_qps']} QPS (recall "
                  f"{row['fp32_recall']}) vs int8-then-rerank "
                  f"{row['int8_qps']} QPS (recall {row['int8_recall']}) "
                  f"-> {row['int8_speedup']}x, recall delta "
                  f"{row['recall_delta']:+.4f}, auto int8="
                  f"{row['auto_path_int8']}")
    return rows_out


def run_semcache(*, rows: int = 4000, n_unique: int = 16, n_trace: int = 80,
                 tenants: int = 3, k: int = 10, n_insert: int = 48,
                 eps_fuzzy: float = 1e-3, seed: int = 0) -> dict:
    """Semantic-cache acceptance sweep (docs/semantic_cache.md).

    One fitted suite over 'part' with a categorical tenant column and
    namespaces bound, then a repeated-query trace (every unique query once,
    then random repeats) served sequentially through ``AsyncServingEngine``
    twice — without and with a ``SemanticCache(eps=0)``. The acceptance
    claims the JSON must carry:

      * ``speedup`` >= 2x: repeats resolve at submit time, zero scan cost;
      * ``miss_recall_delta`` == 0.0: misses run the identical execution
        path, so their oracle recall matches the uncached run exactly;
      * ``replay_parity_mismatches`` == 0: every hit returns the SAME
        ``(ids, scores)`` bits the uncached run computed for that position;
      * ``epoch_swap.stale_hits`` == 0: after insert+compact bumps the
        ``(epoch, n_rows)`` token, no pre-swap entry is ever served
        (``stale_drops`` > 0 shows the flush actually happened);
      * per-tenant accounting from ``ServeReport.tenants``.

    A fuzzy pass (``eps=eps_fuzzy``, repeats perturbed within eps) shows
    the semantic — not just exact — hit predicate."""
    import dataclasses

    import numpy as np

    from repro.bench import datasets, queries
    from repro.core.boomhq import BoomHQ, BoomHQConfig
    from repro.core.executor import recall_at_k
    from repro.core.rewriter import RewriterConfig
    from repro.serve.queue import AsyncServingEngine
    from repro.serve.semcache import SemanticCache
    from repro.vectordb import flat
    from repro.vectordb.table import ScalarCol, Table

    rng = np.random.default_rng(seed + 11)
    base = datasets.make("part", rows=rows, seed=seed)
    tcol = rng.integers(0, tenants, base.n_rows).astype(np.float32)
    schema = dataclasses.replace(
        base.schema,
        scalar_cols=tuple(base.schema.scalar_cols)
        + (ScalarCol("tenant", "cat", tenants),))
    table = Table.from_numpy(
        schema, [np.asarray(v) for v in base.vectors],
        np.concatenate([np.asarray(base.scalars), tcol[:, None]], axis=1))
    t0 = time.time()
    bq = BoomHQ(table, BoomHQConfig(
        n_clusters=16, use_de=False,
        rewriter=RewriterConfig(steps=20, refine_columns=False)))
    bq.fit(queries.gen_workload(table, 12, n_vec_used=2, k=k, seed=seed))
    bq.bind_tenants("tenant")
    print(f"  semcache suite fitted in {time.time() - t0:.0f}s "
          f"({table.n_rows} rows, {tenants} tenants)")

    pool = [dataclasses.replace(q, tenant_id=i % tenants)
            for i, q in enumerate(queries.gen_workload(
                table, n_unique, n_vec_used=2, k=k, seed=seed + 100))]
    # oracle GT over the tenant-FOLDED predicate (what the engine serves)
    gts = [np.asarray(flat.ground_truth(
        table, list(q.query_vectors), list(q.weights),
        bq.resolve_tenant(q).predicates, q.k)[0]) for q in pool]
    # every unique query once, then random repeats — repeats always arrive
    # after their original completed (sequential awaits), so they CAN hit
    trace = list(range(n_unique)) + list(
        rng.integers(0, n_unique, n_trace - n_unique))

    async def serve_seq(eng, qs):
        async with eng:
            t0 = time.perf_counter()
            reqs = [await eng.submit(q) for q in qs]
            dt = time.perf_counter() - t0
        return reqs, dt

    def engine(cache=None):
        return AsyncServingEngine(bq, batch_size=8, max_wait=0.002,
                                  semcache=cache)

    # warm pass populates the jit specializations both timed passes reuse
    asyncio.run(serve_seq(engine(), pool))

    reqs_base, dt_base = asyncio.run(
        serve_seq(engine(), [pool[i] for i in trace]))
    cache = SemanticCache(eps=0.0)
    eng_c = engine(cache)
    reqs_c, dt_c = asyncio.run(
        serve_seq(eng_c, [pool[i] for i in trace]))

    hits = [r.cache_hit for r in reqs_c]
    base_recs = [recall_at_k(np.asarray(r.result[0]), gts[trace[i]])
                 for i, r in enumerate(reqs_base)]
    miss_deltas, parity_bad = [], 0
    for i, r in enumerate(reqs_c):
        rec = recall_at_k(np.asarray(r.result[0]), gts[trace[i]])
        if r.cache_hit:
            b = reqs_base[i].result
            if not (np.array_equal(np.asarray(r.result[0]),
                                   np.asarray(b[0])[: pool[trace[i]].k])
                    and np.array_equal(np.asarray(r.result[1]),
                                       np.asarray(b[1])[: pool[trace[i]].k])):
                parity_bad += 1
        else:
            miss_deltas.append(rec - base_recs[i])
    rep = eng_c.report(gt_ids={r.seq: gts[trace[i]]
                               for i, r in enumerate(reqs_c)})

    # semantic (within-eps) repeats: perturb every repeat inside eps_fuzzy
    fuzz = []
    for j, i in enumerate(trace):
        q = pool[i]
        if j < n_unique:
            fuzz.append(q)
            continue
        delta = eps_fuzzy / 4.0
        fuzz.append(dataclasses.replace(q, query_vectors=tuple(
            np.asarray(v, np.float32)
            + (delta / np.sqrt(v.shape[-1])).astype(np.float32)
            for v in q.query_vectors)))
    reqs_f, _ = asyncio.run(
        serve_seq(engine(SemanticCache(eps=eps_fuzzy)), fuzz))
    fuzz_hits = sum(r.cache_hit for r in reqs_f)
    fuzz_rec = float(np.mean([
        recall_at_k(np.asarray(r.result[0]), gts[trace[j]])
        for j, r in enumerate(reqs_f)]))

    # epoch-swap oracle: populate -> insert+compact -> re-serve. Token bump
    # must flush every pre-swap entry; zero stale results served.
    bq.bind_tiered(hot_capacity=max(n_insert, 8))
    try:
        swap_cache = SemanticCache(eps=0.0)
        eng_s = engine(swap_cache)

        async def swap_phase():
            async with eng_s:
                first = [await eng_s.submit(q) for q in pool]
                warm = [await eng_s.submit(q) for q in pool]
                extra = datasets.make("part", rows=n_insert, seed=seed + 31)
                scal = np.concatenate(
                    [np.asarray(extra.scalars),
                     rng.integers(0, tenants, n_insert)
                        .astype(np.float32)[:, None]], axis=1)
                bq.tiered.insert([np.asarray(v) for v in extra.vectors],
                                 scal)
                bq.tiered.compact()  # epoch e -> e+1
                after = [await eng_s.submit(q) for q in pool]
                return first, warm, after

        _, warm, after = asyncio.run(swap_phase())
        stale_hits = 0
        for r in after:
            if r.cache_hit:
                ids, _ = bq.execute(r.query)
                if not np.array_equal(np.asarray(r.result[0]),
                                      np.asarray(ids)[: r.query.k]):
                    stale_hits += 1
        swap = {
            "pre_swap_hits": sum(r.cache_hit for r in warm),
            "post_swap_hits": sum(r.cache_hit for r in after),
            "stale_drops": swap_cache.stats()["stale_drops"],
            "stale_hits": stale_hits,
            "epoch": bq.tiered.epoch,
        }
    finally:
        bq.unbind_tiered()

    out = {
        "figure": "serving_semantic_cache",
        "rows": table.n_rows, "tenants": tenants,
        "n_unique": n_unique, "n_trace": n_trace, "k": k,
        "qps_nocache": round(len(trace) / dt_base, 1),
        "qps_cache": round(len(trace) / dt_c, 1),
        "speedup": round(dt_base / dt_c, 2),
        "hit_rate": round(sum(hits) / len(hits), 3),
        "n_cache_hits": rep.n_cache_hits,
        "mean_recall_cached_run": round(rep.mean_recall, 3),
        "mean_recall_uncached_run": round(float(np.mean(base_recs)), 3),
        "miss_recall_delta": round(
            float(np.mean(miss_deltas)) if miss_deltas else 0.0, 4),
        "replay_parity_mismatches": parity_bad,
        "fuzzy_eps": eps_fuzzy,
        "fuzzy_hit_rate": round(fuzz_hits / len(reqs_f), 3),
        "fuzzy_mean_recall": round(fuzz_rec, 3),
        "epoch_swap": swap,
        "per_tenant": rep.tenants,
    }
    print(f"  semcache: {out['qps_nocache']} QPS uncached vs "
          f"{out['qps_cache']} QPS cached -> {out['speedup']}x at hit rate "
          f"{out['hit_rate']}; miss recall delta {out['miss_recall_delta']}, "
          f"{parity_bad} parity mismatches; epoch swap: "
          f"{swap['post_swap_hits']} post-swap hits, "
          f"{swap['stale_drops']} stale drops, {swap['stale_hits']} stale "
          f"served; fuzzy(eps={eps_fuzzy}) hit rate {out['fuzzy_hit_rate']} "
          f"recall {out['fuzzy_mean_recall']}")
    return out


def run_graph(*, rows: int = 100_000, n_hard: int = 48, batch_size: int = 16,
              degree: int = 16, metric: str = "l2", k: int = 10,
              seed: int = 0) -> dict:
    """Graph-strategy acceptance on the correlated hard stratum
    (docs/graph_index.md).

    The stratum is built on the sift v→s table, whose ``cluster_id``
    scalar IS the k-means cluster of the vector: an equality predicate
    selects one geometric region, and placing the query near a row of a
    DIFFERENT cluster makes every IVF probe land on disqualified rows —
    the regime PR 5 showed escalating to the exact-scan fallback. Four
    measured rows:

      * ``graph`` — the new third strategy (beam 16 × 8 hops), recall +
        QPS + mean visited rows (its scan budget);
      * ``ivf_probe`` — IVF at a scan budget ≥ the graph's (nprobe
        rounded up, ``max_scan`` at the grid floor, 4×+ the graph's
        visited count): recall collapses, which is WHY this stratum
        escalates;
      * ``exact_full`` — the exact-scan fallback as the serving pipeline
        dispatches it (dense GEMM over all rows, recall 1.0 by
        construction);
      * ``exact_matched`` — the same fallback budgeted down to the
        graph's oracle recall (smallest ``max_candidates`` whose measured
        recall ≥ the graph's, timed on BOTH scoring paths and reported at
        the better of the two) — the matched-recall baseline the
        acceptance compares against.

    The acceptance claims: ``graph`` QPS > both exact rows' QPS at oracle
    recall ≥ the matched row's, and ``ivf_probe`` recall far below both.

    Two further sections feed the planner: (1) ``cost_model`` fits the
    ``CostModel.graph_row_cost`` / ``overhead_graph`` constants from the
    measured timings — the row unit is anchored on the dense exact scan
    (``crossover · n_rows`` units ↔ its measured per-batch wall time), so
    the graph-vs-exact crossover the constants encode reproduces the
    wall-clock ordering; (2) ``mixed_batch`` scans the fitted three-way
    cost surface (``choose_strategy``) over legal knob/batch shapes for a
    regime where each strategy wins, then executes ONE
    ``execute_batch`` over a stream carrying all three plan strategies
    and reports the per-group scoring-path decisions."""
    import numpy as np

    import jax.numpy as jnp

    from repro.bench import datasets
    from repro.core.executor import HybridExecutor, recall_at_k
    from repro.core.query import (
        BEAM_GRID, HOP_GRID, MAX_SCAN_GRID, MHQ, ExecutionPlan,
        SubqueryParams,
    )
    from repro.serve.batch import (
        CANDIDATE_LOCAL, BatchedHybridExecutor, CostModel,
    )
    from repro.vectordb import flat, graph, ivf
    from repro.vectordb.predicates import Predicates

    table = datasets.make("sift", rows=rows, seed=seed, metric=metric)
    n = table.n_rows
    nc = max(32, min(256, n // 2000))
    # the offline build is O(n^2) (~20 min at 100k on CPU): cache the
    # adjacency keyed by everything that determines it
    cache = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         ".graph_cache",
                         f"sift_{rows}_{degree}_{metric}_{seed}.npz")
    t0 = time.time()
    if os.path.exists(cache):
        z = np.load(cache)
        g = graph.GraphIndex(
            neighbors=jnp.asarray(z["neighbors"]),
            entry_points=jnp.asarray(z["entry_points"]), metric=metric)
        build_s = float(z["build_s"])
    else:
        g = graph.build(table.vectors[0], degree, metric=metric)
        build_s = time.time() - t0
        os.makedirs(os.path.dirname(cache), exist_ok=True)
        np.savez(cache, neighbors=np.asarray(g.neighbors),
                 entry_points=np.asarray(g.entry_points), build_s=build_s)
    iv = ivf.build(table.vectors[0], n_clusters=nc, metric=metric)
    print(f"  graph suite built in {time.time() - t0:.0f}s "
          f"({n} rows, degree {degree}, {nc} IVF clusters)")

    # -- the correlated hard stratum ------------------------------------
    clu = np.asarray(table.scalars)[:, 0].astype(int)
    counts = np.bincount(clu)
    good = [c for c in range(counts.shape[0]) if counts[c] >= 2 * k]
    rng = np.random.default_rng(seed + 5)
    vecs = np.asarray(table.vectors[0])
    hard = []
    for _ in range(n_hard):
        c = int(rng.choice(good))
        r = int(rng.choice(np.where(clu != c)[0]))
        qv = (vecs[r] + rng.normal(0, 0.02, vecs.shape[1])).astype(np.float32)
        pred = Predicates.from_conditions(
            table.scalars.shape[1], {0: (float(c), float(c))})
        hard.append(MHQ(query_vectors=(jnp.asarray(qv),), weights=(1.0,),
                        predicates=pred, k=k))
    gts = [np.asarray(flat.ground_truth(
        table, list(q.query_vectors), list(q.weights), q.predicates,
        q.k)[0]) for q in hard]

    hx = HybridExecutor(table, [iv], graphs=[g])
    subs = (SubqueryParams(k_mult=8, nprobe=8, max_scan=MAX_SCAN_GRID[0],
                           iterative=False),)

    def timed(bx, plan, qs=hard, q_gts=gts, bs=batch_size):
        plans = [plan] * len(qs)
        bx.execute_batch(qs[:bs], plans[:bs])  # warm jit
        t0 = time.perf_counter()
        res = []
        for s in range(0, len(qs), bs):
            res.extend(bx.execute_batch(qs[s: s + bs], plans[s: s + bs]))
        dt = time.perf_counter() - t0
        rec = float(np.mean([recall_at_k(ids, gt)
                             for (ids, _), gt in zip(res, q_gts)]))
        return round(rec, 3), round(len(qs) / dt, 1), dt / (len(qs) / bs)

    def visited(bw, nh, m=16):
        nv = []
        for q in hard[:m]:
            _, _, nvis, _ = graph.search(
                g, table.vectors[0], table.scalars, q.predicates,
                q.query_vectors[0], beam_width=bw, n_hops=nh, k=k)
            nv.append(int(nvis))
        return int(np.mean(nv))

    bx = BatchedHybridExecutor(table, [iv], graphs=[g])
    bxl = BatchedHybridExecutor(table, [iv], graphs=[g],
                                cost_model=CostModel(force=CANDIDATE_LOCAL))
    rows_out = []

    plan_g = hx.legalize(ExecutionPlan("graph", subs, beam_width=16,
                                       n_hops=8))
    v_big = visited(16, 8)
    g_rec, g_qps, t_g_big = timed(bx, plan_g)
    rows_out.append({"config": "graph", "recall": g_rec, "qps": g_qps,
                     "scan_rows": v_big,
                     "beam_width": 16, "n_hops": 8})
    print(f"  graph bw16 h8: recall {g_rec} at {g_qps} QPS "
          f"(visits ~{v_big} rows)")

    npb = max(2, -(-v_big // (n // nc)))
    plan_i = hx.legalize(ExecutionPlan("index_scan", (
        SubqueryParams(k_mult=8, nprobe=npb, max_scan=MAX_SCAN_GRID[0],
                       iterative=False),)))
    i_rec, i_qps, t_ix = timed(bxl, plan_i)
    rows_out.append({"config": "ivf_probe", "recall": i_rec, "qps": i_qps,
                     "scan_rows": MAX_SCAN_GRID[0], "nprobe": npb})
    print(f"  ivf nprobe={npb} max_scan={MAX_SCAN_GRID[0]}: recall {i_rec} "
          f"at {i_qps} QPS (budget {MAX_SCAN_GRID[0] / max(v_big, 1):.1f}x "
          f"the graph's)")

    plan_e = hx.legalize(ExecutionPlan("filter_first", subs))
    e_rec, e_qps, t_dense = timed(bx, plan_e)
    rows_out.append({"config": "exact_full", "recall": e_rec, "qps": e_qps,
                     "scan_rows": n})
    print(f"  exact full scan: recall {e_rec} at {e_qps} QPS")

    # smallest exact-scan budget whose recall matches the graph's; timed
    # on both scoring paths, reported at the better (generous baseline)
    matched = None
    for mc in (256, 512, 1024, 2048, 4096, 8192):
        pm = hx.legalize(ExecutionPlan("filter_first", subs,
                                       max_candidates=mc))
        m_rec, m_qps_l, _ = timed(bxl, pm)
        if m_rec >= g_rec:
            _, m_qps_d, _ = timed(bx, pm)
            matched = {"config": "exact_matched", "recall": m_rec,
                       "qps": max(m_qps_l, m_qps_d),
                       "scan_rows": mc,
                       "qps_local": m_qps_l, "qps_dense": m_qps_d}
            break
    if matched is None:  # graph recall above every truncated budget
        matched = {"config": "exact_matched", "recall": e_rec, "qps": e_qps,
                   "scan_rows": n}
    rows_out.append(matched)
    print(f"  exact matched-recall (mc={matched['scan_rows']}): recall "
          f"{matched['recall']} at {matched['qps']} QPS")

    # -- fit the CostModel graph constants ------------------------------
    # unit anchor: the dense exact scan's measured per-batch time is
    # crossover·n_rows units by definition of the strategy crossover, so
    # the fitted (graph_row_cost, overhead_graph) reproduce the measured
    # graph-vs-exact wall-clock ordering at serving shapes.
    cm0 = CostModel()
    unit_s = t_dense / (cm0.crossover * n)
    plan_g2 = hx.legalize(ExecutionPlan("graph", subs, beam_width=4,
                                        n_hops=2))
    v_small = visited(4, 2)
    _, _, t_g_small = timed(bx, plan_g2)
    u_big, u_small = t_g_big / unit_s, t_g_small / unit_s
    c_fit = max(0.05, (u_big - u_small)
                / max(1, batch_size * (v_big - v_small)))
    oh_fit = max(0.0, u_big - batch_size * v_big * c_fit)
    c_fit, oh_fit = round(c_fit, 3), round(oh_fit, 1)
    cost = {"graph_row_cost": c_fit, "overhead_graph": oh_fit,
            "unit_us": round(unit_s * 1e6, 3),
            "visited": {"bw16_h8": v_big, "bw4_h2": v_small},
            "batch_s": {"graph_bw16_h8": round(t_g_big, 4),
                        "graph_bw4_h2": round(t_g_small, 4),
                        "exact_dense": round(t_dense, 4),
                        "ivf_local": round(t_ix, 4)}}
    print(f"  cost fit: graph_row_cost {c_fit}, overhead_graph {oh_fit} "
          f"(dense-anchored unit {cost['unit_us']}us)")

    # -- three-way dispatch in one mixed batch --------------------------
    cm = CostModel(graph_row_cost=c_fit, overhead_graph=oh_fit)
    regimes = {}
    for b in (1, 2, 4, 8, 16, 32, 64, 128):
        for bw in BEAM_GRID:
            for nh in HOP_GRID:
                gs = max(1, int(v_big * (bw * nh) / (16 * 8)))
                for ms in MAX_SCAN_GRID:
                    s = cm.choose_strategy(batch=b, graph_scan=gs,
                                           probe_scan=min(ms, n), n_rows=n)
                    regimes.setdefault(s, {
                        "batch": b, "beam_width": bw, "n_hops": nh,
                        "graph_scan": gs, "probe_scan": min(ms, n)})
    print(f"  three-way regimes found: {sorted(regimes)}")

    mixed_plans = {
        "graph": plan_g,
        "index_scan": plan_i,
        "exact": plan_e,
    }
    stream, plans = [], []
    rng2 = np.random.default_rng(seed + 9)
    for i, q in enumerate(hard[:3 * (len(hard) // 3)]):
        strat = ("graph", "index_scan", "exact")[i % 3]
        stream.append(q)
        plans.append(mixed_plans[strat])
    order = rng2.permutation(len(stream))
    stream = [stream[i] for i in order]
    plans = [plans[i] for i in order]
    bx.dispatcher.take()  # drop warm-up decisions
    res = bx.execute_batch(stream, plans)
    counts, decisions = bx.dispatcher.take()
    keys = sorted({bx._group_key(q, hx.legalize(p))[0]
                   for q, p in zip(stream, plans)})
    mixed = {"batch": len(stream),
             "strategies": sorted({p.strategy for p in plans}),
             "group_kinds": keys,
             "scoring_paths": counts,
             "regimes": regimes,
             "all_three_in_one_batch": keys == ["ff", "gr", "ix"],
             "results": len(res)}
    print(f"  mixed batch of {len(stream)}: groups {keys}, scoring paths "
          f"{counts}")

    out = {
        "figure": "graph_index_hard_stratum",
        "dataset": "sift", "rows": n, "metric": metric, "degree": degree,
        "n_hard": n_hard, "batch_size": batch_size, "k": k,
        "build_s": round(build_s, 1),
        "table": rows_out,
        "cost_model": cost,
        "mixed_batch": mixed,
        "graph_vs_exact_full_speedup": round(g_qps / e_qps, 2),
        "graph_vs_exact_matched_speedup": round(
            g_qps / matched["qps"], 2),
        "graph_recall_minus_matched": round(g_rec - matched["recall"], 4),
    }
    print(f"  acceptance: graph {out['graph_vs_exact_full_speedup']}x vs "
          f"full exact, {out['graph_vs_exact_matched_speedup']}x vs "
          f"matched-recall exact (recall delta "
          f"{out['graph_recall_minus_matched']:+.3f}); ivf recall {i_rec} "
          f"vs graph {g_rec}")
    return out


def run(sizes=None, dataset: str = "part", *, n_stream: int = 64,
        batch_size: int = 32, seed: int = 0, shards=DEFAULT_SHARDS,
        rate: float = DEFAULT_RATE, deadline: float = DEFAULT_DEADLINE
        ) -> dict:
    from benchmarks import common

    sizes = common.FAST if sizes is None else sizes
    suite = common.build_suite(dataset, n_vec_used=2, seed=seed, sizes=sizes)
    stream, gts = _stream_and_gts(suite, n_stream, seed)
    out = {
        "figure": "serving_batched_and_async_sharded",
        "dataset": dataset, "rows": suite.table.n_rows,
        "n_stream": n_stream, "batch_size": batch_size,
        "poisson_rate": rate, "deadline_s": deadline,
    }
    out.update(run_sync_compare(suite, stream, gts, batch_size=batch_size))
    out["async_shards"] = run_async_shards(
        suite, stream, gts, batch_size=batch_size, shards=shards, rate=rate,
        deadline=deadline, seed=seed)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default=None,
                    help="default: part (suite) / sift (--sharded)")
    ap.add_argument("--n-stream", type=int, default=64)
    ap.add_argument("--batch-size", type=int, default=32)
    ap.add_argument("--rate", type=float, default=DEFAULT_RATE,
                    help="Poisson arrival rate (requests/s)")
    ap.add_argument("--deadline", type=float, default=DEFAULT_DEADLINE,
                    help="per-request deadline (s)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny table for a seconds-long sanity run")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--crossover", action="store_true",
                    help="dense vs candidate-local acceptance sweep "
                         "(60k and 500k-row tables) instead of the suite")
    ap.add_argument("--quantized", action="store_true",
                    help="int8-then-rerank vs fp32 candidate-local "
                         "acceptance sweep (60k and 500k-row tables) "
                         "instead of the suite")
    ap.add_argument("--semcache", action="store_true",
                    help="semantic-cache acceptance sweep (repeated-query "
                         "trace, epoch-swap staleness oracle, per-tenant "
                         "accounting) instead of the suite")
    ap.add_argument("--sharded", action="store_true",
                    help="sharded-IVF acceptance sweep (500k rows, 4 "
                         "shards: learned per-shard probing vs exact "
                         "sharded scan vs single-device) instead of the "
                         "suite")
    ap.add_argument("--graph", action="store_true",
                    help="graph-strategy acceptance on the correlated "
                         "hard stratum (graph vs IVF-probe vs exact-scan "
                         "fallback, CostModel constant fit, three-way "
                         "mixed-batch dispatch) instead of the suite")
    ap.add_argument("--rows", type=int, default=500_000,
                    help="table rows for --sharded / --graph (--graph "
                         "caps at 100k: the offline build is O(n^2))")
    ap.add_argument("--shards", type=int, default=4,
                    help="shard count for --sharded")
    ap.add_argument("--mesh", action="store_true",
                    help="force a host-platform device mesh for --sharded "
                         "(default: logical shards — a fake mesh on one "
                         "physical CPU measures the partitioner, not the "
                         "algorithm)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.crossover:
        res = {"figure": "serving_scoring_crossover",
               "table": run_crossover(n_stream=args.n_stream)}
        if args.out:
            with open(args.out, "w") as f:
                json.dump(res, f, indent=2)
        return

    if args.graph:
        res = run_graph(rows=min(args.rows, 100_000))
        if args.out:
            with open(args.out, "w") as f:
                json.dump(res, f, indent=2)
        return

    if args.semcache:
        res = run_semcache()
        if args.out:
            with open(args.out, "w") as f:
                json.dump(res, f, indent=2)
        return

    if args.quantized:
        res = {"figure": "serving_quantized_tier",
               "table": run_quantized(n_stream=args.n_stream)}
        if args.out:
            with open(args.out, "w") as f:
                json.dump(res, f, indent=2)
        return

    # force a 4-device host platform BEFORE jax initializes so the 2/4-shard
    # rows run under shard_map on a real mesh (imports below are lazy for
    # exactly this reason; benchmarks.run imports this module with jax
    # already single-device and gets logical shards instead). The sharded
    # sweep defaults to logical shards, so it only forces under --mesh.
    if not args.sharded or args.mesh:
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                flags + " --xla_force_host_platform_device_count="
                f"{max(max(DEFAULT_SHARDS), args.shards)}").strip()

    if args.sharded:
        res = run_sharded(args.dataset or "sift", rows=args.rows,
                          shards=args.shards, batch_size=args.batch_size,
                          n_stream=args.n_stream, use_mesh=args.mesh)
        if args.out:
            with open(args.out, "w") as f:
                json.dump(res, f, indent=2)
        return

    from benchmarks import common

    sizes = _smoke_sizes() if args.smoke \
        else (common.FULL if args.full else common.FAST)
    res = run(sizes, args.dataset or "part", n_stream=args.n_stream,
              batch_size=args.batch_size, rate=args.rate,
              deadline=args.deadline)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(res, f, indent=2)


if __name__ == "__main__":
    main()
