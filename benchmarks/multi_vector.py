"""Fig. 4 — weighted multi-vector-column hybrid query QPS vs recall.

Part and Aka_title (the two-vector-column tables), BoomHQ vs grid-searched
static plans under pgvector caps and the Milvus/OpenSearch personalities
(independent per-column ANN + merge, uniform λ). The paper reports 77%/64%
average QPS improvements, 2× average speedup at thr=0.8 on Part, >25× peak.
"""
from __future__ import annotations


import numpy as np

from benchmarks import common

DATASETS = ("part", "aka_title")
THRESHOLDS = (0.8, 0.9, 0.95, 0.99)


def run(sizes=common.FAST, datasets=DATASETS, thresholds=THRESHOLDS,
        seed: int = 0) -> dict:
    out = {"figure": "fig4_multi_vector", "rows": [], "speedups": {}}
    for ds in datasets:
        suite = common.build_suite(ds, n_vec_used=2, seed=seed, sizes=sizes)
        profile = common.grid_profile(
            suite.executor, suite.train[: min(16, len(suite.train))], suite.gts)
        gains = []
        for thr in thresholds:
            plan, _ = common.pick_static(profile, thr)
            base = common.eval_static(suite, plan, thr, repeats=sizes["repeats"])
            ours = common.eval_boomhq(suite, thr, repeats=sizes["repeats"])
            gain = ours["qps"] / base["qps"] - 1.0
            gains.append(gain)
            sp = common.speedups(base["lats"], ours["lats"])
            out["rows"].append({
                "dataset": ds, "recall_thr": thr,
                "boomhq_qps": round(ours["qps"], 1),
                "boomhq_recall": round(ours["recall"], 3),
                "static_qps": round(base["qps"], 1),
                "static_recall": round(base["recall"], 3),
                "qps_gain_pct": round(100 * gain, 1), **sp})
            print(f"  fig4 {ds:10s} thr={thr:.2f} gain {100*gain:+.1f}% "
                  f"avg_speedup {sp['avg_speedup']:.2f}x "
                  f"peak {sp['peak_speedup']:.1f}x")
        out["speedups"][ds] = {
            "avg_qps_gain_pct": round(100 * float(np.mean(gains)), 1)}
        print(f"fig4 {ds}: avg QPS gain {out['speedups'][ds]['avg_qps_gain_pct']}% "
              f"(paper: Part +77%, Aka_title +64%)")
    return out


if __name__ == "__main__":
    run()
