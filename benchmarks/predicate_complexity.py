"""Learned plans vs the static default plan on DNF predicate workloads.

The predicate-algebra API (OR-of-ranges, IN-lists, NOTs — compiled to
clause-grid DNF ``PredicateSet``s) opens the workload prior systems restrict:
disjunctive predicates whose selectivity the single-conjunction features
cannot see. This suite fits BoomHQ on a mixed-clause DNF workload and
compares, per clause bucket:

  * learned per-query plans (``BoomHQ.execute``, optimizer overhead
    included) vs ``default_plan`` executed on the same engine;
  * batched serving QPS of the learned path (``ServingEngine``), whose
    group keys now include the clause bucket.

  PYTHONPATH=src python -m benchmarks.predicate_complexity          # FAST
  PYTHONPATH=src python -m benchmarks.predicate_complexity --smoke
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from benchmarks import common
from repro.bench import datasets, queries
from repro.core.boomhq import BoomHQ, BoomHQConfig
from repro.core.data_encoder import DataEncoderConfig
from repro.core.executor import recall_at_k
from repro.core.query import default_plan
from repro.core.rewriter import RewriterConfig
from repro.serve.batch import ServingEngine
from repro.vectordb import flat
from repro.vectordb.predicates import clause_bucket

SMOKE = dict(common.FAST, rows=4000, n_train=16, n_test=12, frozen_steps=25,
             ae_steps=40, rw_steps=100, n_clusters=16)


def _summ(recs, lats):
    lats = np.asarray(lats)
    return {"recall": round(float(np.mean(recs)), 3),
            "lat_ms": round(float(lats.mean() * 1e3), 3),
            "qps": round(float(1.0 / lats.mean()), 1)}


def run(sizes=common.FAST, dataset: str = "part", *, seed: int = 0,
        batch_size: int = 16) -> dict:
    table = datasets.make(dataset, rows=sizes["rows"], seed=seed)
    n = sizes["n_train"] + sizes["n_test"]
    # mixed-complexity training: the rewriter must see conjunctions AND DNF
    wl = queries.gen_dnf_workload(table, n, n_vec_used=2, seed=seed + 1,
                                 clause_counts=(1, 2, 3, 4))
    train, test = wl[: sizes["n_train"]], wl[sizes["n_train"]:]

    bq = BoomHQ(table, BoomHQConfig(
        n_clusters=sizes["n_clusters"],
        encoder=DataEncoderConfig(frozen_steps=sizes["frozen_steps"],
                                  ae_steps=sizes["ae_steps"], sample=4096),
        rewriter=RewriterConfig(steps=sizes["rw_steps"])))
    t0 = time.time()
    bq.fit(train)
    fit_s = time.time() - t0

    gts = {id(q): np.asarray(flat.ground_truth(
        table, list(q.query_vectors), list(q.weights), q.predicates, q.k)[0])
        for q in test}

    repeats = sizes.get("repeats", 2)
    per_bucket: dict = {}
    for q in test:
        cb = clause_bucket(q.predicates)
        dplan = default_plan(q.n_vec, bq.engine)
        ids_l, _, dt_l = bq.execute_timed(q, repeats=repeats)
        ids_d, _, dt_d = bq.executor.execute_timed(q, dplan, repeats=repeats)
        slot = per_bucket.setdefault(cb, {"learned": ([], []),
                                          "default": ([], [])})
        slot["learned"][0].append(recall_at_k(ids_l, gts[id(q)]))
        slot["learned"][1].append(dt_l)
        slot["default"][0].append(recall_at_k(ids_d, gts[id(q)]))
        slot["default"][1].append(dt_d)

    buckets = {}
    for cb in sorted(per_bucket):
        slot = per_bucket[cb]
        buckets[str(cb)] = {
            "n_queries": len(slot["learned"][0]),
            "learned": _summ(*slot["learned"]),
            "default": _summ(*slot["default"]),
        }

    # batched serving of the full DNF test stream (mixed clause buckets)
    engine = ServingEngine(bq, batch_size=batch_size)
    engine.warmup(test)
    _, rep = engine.serve(test, gt_ids=[gts[id(q)] for q in test])

    all_l = ([r for s in per_bucket.values() for r in s["learned"][0]],
             [t for s in per_bucket.values() for t in s["learned"][1]])
    all_d = ([r for s in per_bucket.values() for r in s["default"][0]],
             [t for s in per_bucket.values() for t in s["default"][1]])
    out = {
        "figure": "predicate_complexity_dnf",
        "dataset": dataset, "rows": table.n_rows,
        "n_train": len(train), "n_test": len(test),
        "fit_seconds": round(fit_s, 1),
        "per_clause_bucket": buckets,
        "overall": {"learned": _summ(*all_l), "default": _summ(*all_d)},
        "batched_learned_qps": round(rep.qps, 1),
        "batched_learned_recall": round(rep.mean_recall, 3),
    }
    print(f"  predicate_complexity {dataset}: learned "
          f"{out['overall']['learned']['qps']} QPS @ recall "
          f"{out['overall']['learned']['recall']} vs default "
          f"{out['overall']['default']['qps']} QPS @ recall "
          f"{out['overall']['default']['recall']}; batched learned "
          f"{out['batched_learned_qps']} QPS")
    for cb, row in buckets.items():
        print(f"    C<={cb}: learned {row['learned']} | default {row['default']}")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="part")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    sizes = SMOKE if args.smoke else (common.FULL if args.full else common.FAST)
    res = run(sizes, args.dataset)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(res, f, indent=2)


if __name__ == "__main__":
    main()
