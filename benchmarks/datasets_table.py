"""Table 1 — the 11-dataset benchmark overview."""
from __future__ import annotations

from repro.bench.datasets import SPECS, table_row


def run(**_) -> dict:
    rows = [table_row(n) for n in SPECS]
    print(f"  {'Benchmark':<12}{'Type':<8}{'#Rows':>12}  #Dimension")
    for r in rows:
        print(f"  {r['Benchmark']:<12}{r['Type']:<8}{r['Rows']:>12,}  "
              f"{r['Dimension']}")
    return {"figure": "table1_datasets", "rows": rows}


if __name__ == "__main__":
    run()
