"""Fig. 5 — effect of the weight w₁ on per-query runtime at recall 0.90.

When w₁ is heavily skewed BoomHQ switches to the single-index strategy;
the static plan pays for both columns regardless.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks import common


def run(sizes=common.FAST, dataset: str = "part", seed: int = 0,
        thr: float = 0.9) -> dict:
    suite = common.build_suite(dataset, n_vec_used=2, seed=seed, sizes=sizes)
    plan, _ = common.grid_search_static(
        suite.executor, suite.train[: min(16, len(suite.train))], suite.gts, thr)
    buckets = {}
    for q in suite.test:
        q2 = dataclasses.replace(q, recall_target=thr)
        w1 = float(q.weights[0])
        b = min(int(w1 * 5), 4)  # 5 buckets over [0,1]
        _, _, dt_ours = suite.bq.execute_timed(q2, repeats=sizes["repeats"])
        _, _, dt_base = suite.executor.execute_timed(q2, plan,
                                                     repeats=sizes["repeats"])
        buckets.setdefault(b, []).append((w1, dt_ours, dt_base))
    rows = []
    for b in sorted(buckets):
        ws, ours, base = zip(*buckets[b])
        rows.append({"w1_bucket": f"[{b/5:.1f},{(b+1)/5:.1f})",
                     "n": len(ws),
                     "boomhq_ms": round(1e3 * float(np.mean(ours)), 2),
                     "static_ms": round(1e3 * float(np.mean(base)), 2)})
        print(f"  fig5 w1∈{rows[-1]['w1_bucket']} n={rows[-1]['n']:2d} "
              f"BoomHQ {rows[-1]['boomhq_ms']:7.2f}ms "
              f"static {rows[-1]['static_ms']:7.2f}ms")
    return {"figure": "fig5_weight_skew", "dataset": dataset, "rows": rows}


if __name__ == "__main__":
    run()
