"""Fig. 7 — ablation study: remove each component, measure QPS at recall 0.9.

Variants: full BoomHQ, w.o. DE (data encoder), w.o. QE (all query features),
w.o. QE-Stats, w.o. QE-GSE, w.o. QE-LNP.
"""
from __future__ import annotations


from benchmarks import common

VARIANTS = {
    "BoomHQ": {},
    "w.o. DE": {"use_de": False},
    "w.o. QE": {"use_stats": False, "use_gse": False, "use_lnp": False},
    "w.o. QE-Stats": {"use_stats": False},
    "w.o. QE-GSE": {"use_gse": False},
    "w.o. QE-LNP": {"use_lnp": False},
}


def run(sizes=common.FAST, dataset: str = "part", seed: int = 0,
        thr: float = 0.9, n_vec_used: int = 2) -> dict:
    out = {"figure": "fig7_ablation", "dataset": dataset, "rows": []}
    for name, overrides in VARIANTS.items():
        suite = common.build_suite(dataset, n_vec_used=n_vec_used, seed=seed,
                                   sizes=sizes, boomhq_overrides=overrides)
        res = common.eval_boomhq(suite, thr, repeats=sizes["repeats"])
        out["rows"].append({"variant": name, "qps": round(res["qps"], 1),
                            "recall": round(res["recall"], 3)})
        print(f"  fig7 {name:14s} qps={res['qps']:8.1f} recall={res['recall']:.3f}")
    return out


if __name__ == "__main__":
    run()
