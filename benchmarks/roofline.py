"""§Roofline — format the dry-run sweep (dryrun_results.jsonl) as the
per-(arch × shape × mesh) roofline table: the three terms, the dominant
bottleneck, and the MODEL_FLOPS/HLO_FLOPS usefulness ratio.
"""
from __future__ import annotations

import json
import os


def load(path: str = "dryrun_results.jsonl") -> list[dict]:
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return [json.loads(l) for l in f if l.strip()]


def fmt_table(recs: list[dict], mesh: str = "16x16") -> str:
    hdr = (f"| arch | shape | compute s | memory s | collective s | dominant "
           f"| useful | peak GiB |\n|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in recs:
        if r.get("mesh") != mesh:
            continue
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skipped | — | — |")
            continue
        if "error" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"ERROR | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
            f"{r['dominant'].replace('_s','')} | "
            f"{r['useful_flop_ratio']:.2f} | {r['peak_hbm_gib']:.1f} |")
    return "\n".join(lines)


def run(path: str = "dryrun_results.jsonl", **_) -> dict:
    recs = load(path)
    ok = [r for r in recs if "error" not in r and "skipped" not in r]
    sk = [r for r in recs if "skipped" in r]
    er = [r for r in recs if "error" in r]
    print(f"  roofline: {len(ok)} compiled cells, {len(sk)} documented skips, "
          f"{len(er)} errors (from {path})")
    if ok:
        doms = {}
        for r in ok:
            doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
        print(f"  dominant-term distribution: {doms}")
        worst = sorted(ok, key=lambda r: r["useful_flop_ratio"])[:3]
        for w in worst:
            print(f"  lowest useful-flops: {w['arch']} × {w['shape']} × "
                  f"{w['mesh']} -> {w['useful_flop_ratio']:.3f}")
    return {"figure": "roofline", "n_ok": len(ok), "n_skipped": len(sk),
            "n_error": len(er)}


if __name__ == "__main__":
    print(fmt_table(load()))
    run()
