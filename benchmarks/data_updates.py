"""Fig. 6 — impact of (skewed) data inserts on QPS at recall 0.90.

New rows follow a SHIFTED distribution vs the original table (the paper's
challenging scenario). Compared: BoomHQ with incremental fine-tuning of the
data encoder, BoomHQ frozen (no update), and the static plan.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks import common
from repro.core.executor import recall_at_k
from repro.vectordb import flat

RATIOS = (0.005, 0.01, 0.05, 0.1, 0.5, 1.0)


def _skewed_insert(table, n_new: int, seed: int):
    """Rows whose vectors are shifted and whose scalars are re-correlated."""
    rng = np.random.default_rng(seed)
    vecs = []
    for i, vc in enumerate(table.schema.vector_cols):
        base = np.asarray(table.vectors[i])
        mu = base.mean(axis=0) + 0.8 * base.std(axis=0)  # distribution shift
        vecs.append((mu[None] + 0.4 * rng.normal(
            size=(n_new, vc.dim))).astype(np.float32))
    scal = np.asarray(table.scalars)
    idx = rng.integers(0, scal.shape[0], n_new)
    new_scal = scal[idx].copy()
    m = new_scal.shape[1]
    new_scal[:, m - 1] = new_scal[:, m - 1] * 1.5 + 1.0  # shift a numeric col
    return vecs, new_scal.astype(np.float32)


def run(sizes=common.FAST, dataset: str = "part", seed: int = 0,
        thr: float = 0.9, ratios=RATIOS) -> dict:
    suite = common.build_suite(dataset, n_vec_used=2, seed=seed, sizes=sizes)
    base_rows = suite.table.n_rows
    plan, _ = common.grid_search_static(
        suite.executor, suite.train[: min(16, len(suite.train))], suite.gts, thr)

    def measure(bq, executor):
        recs, lats = [], []
        for q in suite.test:
            q2 = dataclasses.replace(q, recall_target=thr)
            gt, _ = flat.ground_truth(bq.table, list(q.query_vectors),
                                      list(q.weights), q.predicates, q.k)
            ids, _, dt = bq.execute_timed(q2, repeats=sizes["repeats"])
            recs.append(recall_at_k(ids, gt))
            lats.append(dt)
        return float(np.mean(recs)), float(1.0 / np.mean(lats))

    rows = []
    inserted = 0
    for r in ratios:
        target = int(base_rows * r)
        add = target - inserted
        if add > 0:
            vecs, scal = _skewed_insert(suite.bq.table, add, seed + int(r * 1e4))
            suite.bq.insert(vecs, scal, finetune=True)
            inserted = target
        rec, qps = measure(suite.bq, suite.bq.executor)
        rows.append({"insert_ratio": r, "boomhq_qps": round(qps, 1),
                     "boomhq_recall": round(rec, 3)})
        print(f"  fig6 ratio={r:<6} BoomHQ qps={qps:8.1f} recall={rec:.3f}")
    return {"figure": "fig6_data_updates", "dataset": dataset, "rows": rows}


if __name__ == "__main__":
    run()
