"""Fig. 6 — impact of (skewed) data inserts on QPS at recall 0.90, plus the
mixed streaming-ingest run over the tiered table (``run_mixed``).

New rows follow a SHIFTED distribution vs the original table (the paper's
challenging scenario). ``run`` compares the legacy eager-insert path at
stepped insert ratios; ``run_mixed`` drives a Poisson open-loop query
stream through ``AsyncServingEngine`` over a ``bind_tiered`` instance while
inserts land mid-stream — measuring QPS, p50/p99, per-request recall
against each request's OWN snapshot, and the zero-pause evidence: with
background compaction no request may wait longer than batch formation plus
the worker's batch executions.
"""
from __future__ import annotations

import asyncio
import dataclasses
import json
import os
import time

import numpy as np

from benchmarks import common
from repro.core.executor import recall_at_k
from repro.vectordb import flat

RATIOS = (0.005, 0.01, 0.05, 0.1, 0.5, 1.0)
RESULTS_PATH = os.path.join(os.path.dirname(__file__), "results",
                            "data_updates.json")


def _skewed_insert(table, n_new: int, seed: int):
    """Rows whose vectors are shifted and whose scalars are re-correlated."""
    rng = np.random.default_rng(seed)
    vecs = []
    for i, vc in enumerate(table.schema.vector_cols):
        base = np.asarray(table.vectors[i])
        mu = base.mean(axis=0) + 0.8 * base.std(axis=0)  # distribution shift
        vecs.append((mu[None] + 0.4 * rng.normal(
            size=(n_new, vc.dim))).astype(np.float32))
    scal = np.asarray(table.scalars)
    idx = rng.integers(0, scal.shape[0], n_new)
    new_scal = scal[idx].copy()
    m = new_scal.shape[1]
    new_scal[:, m - 1] = new_scal[:, m - 1] * 1.5 + 1.0  # shift a numeric col
    return vecs, new_scal.astype(np.float32)


def run(sizes=common.FAST, dataset: str = "part", seed: int = 0,
        thr: float = 0.9, ratios=RATIOS) -> dict:
    suite = common.build_suite(dataset, n_vec_used=2, seed=seed, sizes=sizes)
    base_rows = suite.table.n_rows
    plan, _ = common.grid_search_static(
        suite.executor, suite.train[: min(16, len(suite.train))], suite.gts, thr)

    def measure(bq, executor):
        recs, lats = [], []
        for q in suite.test:
            q2 = dataclasses.replace(q, recall_target=thr)
            gt, _ = flat.ground_truth(bq.table, list(q.query_vectors),
                                      list(q.weights), q.predicates, q.k)
            ids, _, dt = bq.execute_timed(q2, repeats=sizes["repeats"])
            recs.append(recall_at_k(ids, gt))
            lats.append(dt)
        return float(np.mean(recs)), float(1.0 / np.mean(lats))

    rows = []
    inserted = 0
    for r in ratios:
        target = int(base_rows * r)
        add = target - inserted
        if add > 0:
            vecs, scal = _skewed_insert(suite.bq.table, add, seed + int(r * 1e4))
            suite.bq.insert(vecs, scal, finetune=True)
            inserted = target
        rec, qps = measure(suite.bq, suite.bq.executor)
        rows.append({"insert_ratio": r, "boomhq_qps": round(qps, 1),
                     "boomhq_recall": round(rec, 3)})
        print(f"  fig6 ratio={r:<6} BoomHQ qps={qps:8.1f} recall={rec:.3f}")
    return {"figure": "fig6_data_updates", "dataset": dataset, "rows": rows}


def _snapshot_recall(query, ids, snap, gt_cache) -> float:
    """Recall of one result against the brute-force ground truth of the
    snapshot's logical table — the rows that were actually serveable when
    the batch cut. Ground truths are cached per (snapshot, query)."""
    key = (id(snap), id(query))
    if key not in gt_cache:
        tables = gt_cache.setdefault("_tables", {})
        if id(snap) not in tables:
            from repro.vectordb.table import Table
            t = snap.cold.table
            vecs = [np.asarray(v) for v in t.vectors]
            scal = np.asarray(t.scalars)
            for view in snap.hot_views:
                vecs = [np.concatenate([a, b[: view.count]])
                        for a, b in zip(vecs, view.np_vectors)]
                scal = np.concatenate(
                    [scal, view.np_scalars[: view.count]])
            tables[id(snap)] = Table.from_numpy(t.schema, vecs, scal)
        gt, _ = flat.ground_truth(
            tables[id(snap)], list(query.query_vectors),
            list(query.weights), query.predicates, query.k)
        gt_cache[key] = np.asarray(gt)
    return recall_at_k(np.asarray(ids), gt_cache[key])


def run_mixed(sizes=common.FAST, dataset: str = "part", seed: int = 0,
              thr: float = 0.9, insert_ratio: float = 0.1,
              hot_capacity: int = 2048, n_requests: int = 96,
              batch_size: int = 16, max_wait: float = 0.02,
              utilization: float = 0.6) -> dict:
    """Mixed insert+query open-loop run over the tiered table.

    Poisson arrivals at ``utilization`` of the measured warm batch
    throughput; ``insert_ratio`` of the base rows lands in chunks spread
    across the stream, forcing ≥1 background compaction (hot capacity is
    sized under the total insert volume). Writes ``RESULTS_PATH``."""
    from repro.serve.queue import AsyncServingEngine

    suite = common.build_suite(dataset, n_vec_used=2, seed=seed, sizes=sizes)
    bq = suite.bq
    base_rows = suite.table.n_rows
    stream = [dataclasses.replace(suite.test[i % len(suite.test)],
                                  recall_target=thr)
              for i in range(n_requests)]

    bq.bind_tiered(hot_capacity=hot_capacity)
    # pre-insert tiered baseline (hot empty — identical to build-once path)
    pre_recs = [recall_at_k(np.asarray(ids), suite.gts[id(q)])
                for q, (ids, _) in zip(suite.test,
                                       bq.execute_batch(suite.test))]
    pre_recall = float(np.mean(pre_recs))

    # warm throughput -> Poisson rate at the target utilization
    t0 = time.perf_counter()
    bq.execute_batch(stream[:batch_size])
    warm_batch_s = time.perf_counter() - t0
    lam = utilization * batch_size / max(warm_batch_s, 1e-6)
    rng = np.random.default_rng(seed + 17)
    gaps = rng.exponential(1.0 / lam, n_requests - 1).tolist()

    # instrument execution + compaction spans (wall-clock evidence)
    exec_spans = []  # (start, end, query objects) per worker batch
    compaction_spans = []  # (start, end) per background compaction
    inner_exec = bq.execute_batch
    inner_compact = bq.tiered.compact

    def timed_exec(queries, **kw):
        t = time.perf_counter()
        try:
            return inner_exec(queries, **kw)
        finally:
            exec_spans.append((t, time.perf_counter(), list(queries)))

    def timed_compact():
        t = time.perf_counter()
        try:
            return inner_compact()
        finally:
            compaction_spans.append((t, time.perf_counter()))

    bq.execute_batch = timed_exec
    bq.tiered.compact = timed_compact

    n_insert = int(base_rows * insert_ratio)
    n_chunks = 8
    chunk = -(-n_insert // n_chunks)

    async def drive():
        # perf_counter clock: arrivals land on the same timeline as the
        # instrumented execution/compaction spans
        eng = AsyncServingEngine(bq, batch_size=batch_size,
                                 max_wait=max_wait,
                                 clock=time.perf_counter)

        async def ingest():
            done = 0
            while done < n_insert:
                take = min(chunk, n_insert - done)
                vecs, scal = _skewed_insert(suite.table, take,
                                            seed + 31 + done)
                await asyncio.get_running_loop().run_in_executor(
                    None, bq.insert, vecs, scal)
                done += take
                await asyncio.sleep(n_requests / lam / n_chunks / 2)

        async with eng:
            ing = asyncio.ensure_future(ingest())
            tasks = []
            for i, q in enumerate(stream):
                if i > 0:
                    await asyncio.sleep(gaps[i - 1])
                tasks.append(asyncio.ensure_future(eng.submit(q)))
            reqs = await asyncio.gather(*tasks)
            await ing
        return eng, reqs

    t_start = time.perf_counter()
    eng, reqs = asyncio.run(drive())
    wall = time.perf_counter() - t_start
    bq.execute_batch = inner_exec
    bq.tiered.compact = inner_compact

    ok = [r for r in reqs if r.status == "ok"]
    lats = np.asarray([r.latency for r in ok], np.float64)
    gt_cache: dict = {}
    recs = [_snapshot_recall(r.query, r.result[0], r.snapshot, gt_cache)
            for r in ok]

    # zero-pause evidence — "no batch older than max_wait + one execution":
    # with one execution worker, batch i+1 must start as soon as BOTH its
    # cut deadline (oldest arrival + max_wait) and the in-flight batch i
    # have passed. Any extra idle gap means serving stalled on something
    # else — a compaction pausing the worker would show up here as a gap
    # the length of the compaction. (Total latency is NOT the criterion:
    # epoch-swap recompiles inflate queue backlog honestly, p99 reports
    # that; the pause criterion is worker idleness with work pending.)
    # (engine runs on clock=time.perf_counter, same clock as the spans)
    arrival_of = {id(r.query): r.arrival for r in reqs}
    slack = 0.25  # asyncio scheduling + host-transfer jitter
    idle_gaps, prev_end = [], None
    for start, end, qs in exec_spans:
        oldest = min((arrival_of[id(q)] for q in qs if id(q) in arrival_of),
                     default=None)
        if oldest is None:
            continue  # warmup batches executed outside the engine
        cut_deadline = oldest + max_wait
        ready = cut_deadline if prev_end is None \
            else max(cut_deadline, prev_end)
        idle_gaps.append(start - ready)
        prev_end = end
    violations = int(np.sum(np.asarray(idle_gaps) > slack))
    max_exec = max(e - s for s, e, _q in exec_spans)
    pause_bound = max_wait + max_exec + slack

    # post-insert full-stream recall on the SAME workload, hot+cold union
    final_snap = bq.tiered.snapshot()
    post_cache: dict = {}
    post_recs = [
        _snapshot_recall(q, ids, final_snap, post_cache)
        for q, (ids, _) in zip(suite.test,
                               bq.execute_batch(suite.test,
                                                snapshot=final_snap))]
    post_recall = float(np.mean(post_recs))

    out = {
        "figure": "tiered_mixed_ingest", "dataset": dataset,
        "base_rows": base_rows, "n_requests": n_requests,
        "n_inserted": bq.tiered.n_inserted,
        "insert_ratio": insert_ratio, "hot_capacity": hot_capacity,
        "n_compactions": bq.tiered.n_compactions,
        "epoch": bq.tiered.epoch,
        "max_compaction_s": round(max(e - s for s, e in compaction_spans), 3)
        if compaction_spans else 0.0,
        "qps": round(len(ok) / wall, 1),
        "p50_ms": round(float(np.percentile(lats, 50) * 1e3), 2),
        "p99_ms": round(float(np.percentile(lats, 99) * 1e3), 2),
        "mean_recall": round(float(np.mean(recs)), 3),
        "pre_insert_recall": round(pre_recall, 3),
        "post_insert_recall": round(post_recall, 3),
        "recall_delta": round(post_recall - pre_recall, 3),
        "n_timed_out": sum(r.status != "ok" for r in reqs),
        "pause_bound_ms": round(pause_bound * 1e3, 1),
        "max_idle_gap_ms": round(max(idle_gaps) * 1e3, 1)
        if idle_gaps else 0.0,
        "pause_violations": violations,
        "zero_pause": violations == 0,
    }
    assert out["n_compactions"] >= 1, "stream never triggered compaction"
    assert out["zero_pause"], (
        f"{violations} requests stalled past {pause_bound * 1e3:.0f}ms")
    assert out["recall_delta"] >= -0.02, out
    os.makedirs(os.path.dirname(RESULTS_PATH), exist_ok=True)
    with open(RESULTS_PATH, "w") as f:
        json.dump(out, f, indent=1)
    print(f"  tiered qps={out['qps']} p50={out['p50_ms']}ms "
          f"p99={out['p99_ms']}ms recall={out['mean_recall']} "
          f"compactions={out['n_compactions']} pauses={violations}")
    return out


if __name__ == "__main__":
    run()
