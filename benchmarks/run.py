"""Benchmark orchestrator — one entry per paper table/figure.

  python -m benchmarks.run            # moderate sizes (default)
  python -m benchmarks.run --fast     # CI-speed
  python -m benchmarks.run --only fig3,fig4
  python -m benchmarks.run --full     # paper-scale-ish (slow)

Writes benchmarks_results.json next to the repo root.
"""
from __future__ import annotations

import argparse
import json
import time

from benchmarks import (
    ablation, common, cross_engine, data_updates, datasets_table,
    kernels_bench, multi_vector, predicate_complexity, roofline, serving,
    single_vector, weight_skew,
)

BENCHES = {
    "table1": datasets_table.run,
    "fig3": single_vector.run,
    "fig4": multi_vector.run,
    "fig5": weight_skew.run,
    "fig6": data_updates.run,
    "tiered": data_updates.run_mixed,
    "sec54": cross_engine.run,
    "fig7": ablation.run,
    "kernels": kernels_bench.run,
    "roofline": roofline.run,
    "serving": serving.run,
    "predicate_complexity": predicate_complexity.run,
}

NO_SIZES = ("table1", "kernels", "roofline")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--out", default="benchmarks_results.json")
    args = ap.parse_args()

    sizes = common.FULL if args.full else common.FAST
    if not args.fast and not args.full:  # default: moderate
        sizes = dict(common.FAST, n_train=32, rw_steps=300)

    names = list(BENCHES) if not args.only else args.only.split(",")
    results, t_total = {}, time.time()
    for name in names:
        fn = BENCHES[name]
        print(f"=== {name} ===", flush=True)
        t0 = time.time()
        try:
            results[name] = fn() if name in NO_SIZES else fn(sizes=sizes)
            results[name]["seconds"] = round(time.time() - t0, 1)
        except Exception as e:  # noqa: BLE001
            results[name] = {"error": f"{type(e).__name__}: {e}"}
            print(f"  FAILED: {results[name]['error']}")
        print(f"  ({time.time() - t0:.0f}s)", flush=True)
    results["total_seconds"] = round(time.time() - t_total, 1)
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1)
    print(f"\nwrote {args.out} ({results['total_seconds']:.0f}s total)")
    errs = [n for n in names if "error" in results.get(n, {})]
    if errs:
        raise SystemExit(f"benchmarks failed: {errs}")


if __name__ == "__main__":
    main()
