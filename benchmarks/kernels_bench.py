"""Kernel micro-benchmark: fused masked_topk / int8_scan vs the jnp oracle.

On this CPU container the Pallas kernels execute in interpret mode, so the
meaningful numbers are (a) correctness parity with the oracle and (b) the
HBM-byte model: the int8 scan reads 4× fewer DB bytes per query — the
memory-roofline win on the full-scan path (EXPERIMENTS.md §Perf boomhq row).
"""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def run(n: int = 20_000, d: int = 128, m: int = 3, k: int = 10, **_) -> dict:
    rng = np.random.default_rng(0)
    vecs = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    scal = jnp.asarray(rng.uniform(0, 10, (n, m)), jnp.float32)
    lo = jnp.asarray([3.0] + [-np.inf] * (m - 1), jnp.float32)
    hi = jnp.asarray([7.0] + [np.inf] * (m - 1), jnp.float32)
    act = jnp.asarray([True] + [False] * (m - 1))
    q = jnp.asarray(rng.normal(size=(d,)), jnp.float32)

    s_k, i_k = ops.masked_topk(q, vecs, scal, lo, hi, act, k=k)
    s_r, i_r = ref.masked_topk_ref(q, vecs, scal, lo, hi, act, n, k=k)
    parity = bool(np.allclose(np.asarray(s_k), np.asarray(s_r), atol=1e-4))

    qv, sc = ops.quantize_rows(vecs)
    s_q, i_q = ops.int8_masked_topk(q, qv, sc, scal, lo, hi, act, k=k)
    rec = len(set(map(int, np.asarray(i_q))) & set(map(int, np.asarray(i_r)))) / k

    def t(f, reps=3):
        f()
        jax.block_until_ready(f())
        t0 = time.perf_counter()
        for _ in range(reps):
            jax.block_until_ready(f())
        return (time.perf_counter() - t0) / reps * 1e3

    ms_ref = t(lambda: ref.masked_topk_ref(q, vecs, scal, lo, hi, act, n, k=k))
    fp32_bytes = n * d * 4
    int8_bytes = n * d * 1 + n * 4
    out = {
        "figure": "kernels_bench",
        "oracle_parity": parity,
        "int8_recall_vs_fp32": rec,
        "ref_scan_ms_cpu": round(ms_ref, 2),
        "db_bytes_fp32": fp32_bytes,
        "db_bytes_int8": int8_bytes,
        "hbm_reduction": round(fp32_bytes / int8_bytes, 2),
    }
    print(f"  kernels: parity={parity} int8_recall={rec:.2f} "
          f"HBM bytes/query {fp32_bytes/2**20:.1f}MiB -> "
          f"{int8_bytes/2**20:.1f}MiB ({out['hbm_reduction']}x)")
    return out


if __name__ == "__main__":
    run()
