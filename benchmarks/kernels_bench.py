"""Kernel micro-benchmarks: fused kernels vs oracles, dense-vs-local crossover.

On this CPU container the Pallas kernels execute in interpret mode, so the
meaningful numbers are (a) correctness parity with the oracle, (b) the
HBM-byte model (the int8 scan reads 4× fewer DB bytes per query), and
(c) the dense-vs-candidate-local CROSSOVER sweep: one (B, n) GEMM + masked
top-k over ALL rows versus the fused gather+score over only each query's
``scan`` candidate rows (``kernels.gather_score``, executing its off-TPU
reference path — the same code the serving dispatcher runs). The sweep
calibrates ``serve.batch.CostModel.crossover``: candidate-local wins while
``B·scan / n_rows`` stays below the reported measured ratio.
"""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels.gather_score import gather_score_topk, gather_score_topk_int8

NEG = -1e30


def _timeit(f, reps=3):
    jax.block_until_ready(f())  # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(f())
    return (time.perf_counter() - t0) / reps * 1e3


# sweep points as work ratios B·scan/n — scan widths scale with the table
# so the sweep stays cheap on small benchmark runs and spans the same
# decision space on large ones
SWEEP_RATIOS = (0.07, 0.27, 1.1, 4.4, 17.5)


def crossover_sweep(n: int = 60_000, d: int = 128, b: int = 32, m: int = 3,
                    k: int = 10, scans=None,
                    precision: str = "fp32") -> list[dict]:
    """Dense batched scoring vs candidate-local fused gather+score.

    Dense cost is scan-independent (every row is scored); candidate-local
    scales with ``b·scan``. Each row reports both times, the work ratio
    ``b·scan/n`` and the speedup — the largest ratio with speedup > 1 is
    the measured crossover the ``CostModel`` default should sit under.

    ``precision="int8"`` runs the quantized tier as the candidate-local
    side (int8 gather→score→mask then exact fp32 rerank of the top-α·k) —
    the sweep that calibrates ``CostModel.crossover_int8``. The dense
    baseline stays fp32: there is no dense int8 path."""
    if scans is None:
        scans = tuple(max(64, int(r * n / b)) for r in SWEEP_RATIOS)
    from repro.vectordb.predicates import Predicates, stack

    rng = np.random.default_rng(0)
    vecs = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    scal = jnp.asarray(rng.uniform(0, 10, (n, m)), jnp.float32)
    q_b = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
    w_b = jnp.ones((b, 1), jnp.float32)
    pred_b = stack([Predicates.from_conditions(m, {0: (2.0, 8.0)})
                    for _ in range(b)])

    @jax.jit
    def dense(qb, lo, hi):
        ws = qb @ vecs.T  # (b, n) — one GEMM over ALL rows
        ok = jnp.all((scal >= lo) & (scal <= hi)
                     | ~jnp.asarray([True] + [False] * (m - 1)), axis=1)
        masked = jnp.where(ok[None, :], ws, NEG)
        return jax.lax.top_k(masked, k)

    lo = jnp.asarray([2.0] + [-np.inf] * (m - 1), jnp.float32)
    hi = jnp.asarray([8.0] + [np.inf] * (m - 1), jnp.float32)
    ms_dense = _timeit(lambda: dense(q_b, lo, hi))

    if precision == "int8":
        v8, sc8 = ops.quantize_rows(vecs)

        @jax.jit
        def local_fn(c):
            return gather_score_topk_int8(
                c, (vecs,), (v8,), (sc8,), (q_b,), w_b, scal, pred_b,
                k=k, metric="dot", use_kernel=False)
    else:
        @jax.jit
        def local_fn(c):
            # jitted like the serving paths (gather_score_topk is traceable
            # and always called inside the executor's jitted graphs)
            return gather_score_topk(c, (vecs,), (q_b,), w_b, scal, pred_b,
                                     k=k, metric="dot", use_kernel=False)

    rows = []
    for scan in scans:
        cand = jnp.asarray(rng.integers(0, n, size=(b, scan)), jnp.int32)
        ms_local = _timeit(lambda c=cand: local_fn(c))
        ratio = b * scan / n
        rows.append({
            "n_rows": n, "batch": b, "scan": scan, "precision": precision,
            "work_ratio": round(ratio, 3),
            "dense_ms": round(ms_dense, 2),
            "local_ms": round(ms_local, 2),
            "speedup": round(ms_dense / ms_local, 2),
        })
        print(f"  crossover[{precision}] n={n} B={b} scan={scan}: dense "
              f"{ms_dense:.1f}ms vs local {ms_local:.1f}ms -> "
              f"{rows[-1]['speedup']}x (B·scan/n = {ratio:.2f})")
    return rows


def measured_overhead_rows(rows: list[dict], *, scan: int, n_rows: int,
                           crossover: float = 0.136) -> float:
    """``CostModel.overhead`` from an affine fit of the candidate-local
    per-batch times: ``t(B) = OH_ms + slope·B`` (slope = per-gathered-row
    cost × scan). Dividing the fixed intercept by the per-row cost converts
    it to the gathered-row units the decision inequality
    ``B·scan + overhead <= crossover·n`` uses. The fit is then clamped so
    every MEASURED winner keeps winning under the final constants — near
    the boundary the decisions, not the noisy intercept, are the ground
    truth."""
    bs = np.asarray([r["batch"] for r in rows], np.float64)
    ts = np.asarray([r["local_ms"] for r in rows], np.float64)
    slope, oh_ms = np.polyfit(bs, ts, 1)
    per_row_ms = max(slope, 1e-9) / scan
    oh = float(max(0.0, oh_ms) / per_row_ms)
    wins = [crossover * n_rows - r["batch"] * r["scan"]
            for r in rows if r["local_wins"]]
    if wins:
        oh = min(oh, max(0.0, min(wins)))
    return round(oh)


def overhead_sweep(n: int = 500_000, k: int = 10, scan: int = 2048,
                   nprobe: int = 16, k_mult: int = 4,
                   batches=(4, 8, 16, 32), dataset: str = "sift",
                   seed: int = 0, precision: str = "fp32",
                   crossover: float = 0.136) -> dict:
    """Calibrate the candidate-local path's FIXED per-batch overhead
    END-TO-END: drive the real batched executor (fixed legalized plan,
    each scoring path forced) across batch sizes.

    The fixed costs the model must capture — per-query probe slot
    selection, group dispatch, iterative re-expansion host syncs — live in
    the serving path, NOT in the fused kernel alone, so the calibration
    times whole executor batches per batch size, fits the affine
    ``t(B) = OH + slope·B`` and converts the intercept to gathered-row
    units:

        candidate-local wins  iff  B·scan + overhead <= crossover·n

    This is the term that closes the ROADMAP's small-batch mispredict:
    without it ``B·scan`` shrinks with the batch while the fixed cost does
    not, so the model sent every near-boundary tiny batch candidate-local.
    The dense column is measured alongside as the ground truth the
    calibrated decisions are checked against."""
    from repro.bench import datasets, queries
    from repro.core.query import ExecutionPlan, SubqueryParams
    from repro.serve.batch import (
        BatchedHybridExecutor, CANDIDATE_LOCAL, DENSE, CostModel,
    )
    from repro.vectordb import ivf as _ivf

    table = datasets.make(dataset, rows=n, seed=seed)
    n_vec = table.schema.n_vec
    nc = max(64, min(512, table.n_rows // 2000))
    idx = [_ivf.build(v, nc, seed=i, metric=table.schema.metric)
           for i, v in enumerate(table.vectors)]
    plan = ExecutionPlan("index_scan", tuple(
        SubqueryParams(k_mult=k_mult, nprobe=nprobe, max_scan=scan,
                       iterative=True) for _ in range(n_vec)),
        precision=precision)
    rows = []
    for b in batches:
        wl = queries.gen_workload(table, b, n_vec_used=min(2, n_vec),
                                  seed=seed + 100)
        plans = [plan] * len(wl)
        row = {"batch": b, "scan": scan}
        for label, force in (("dense", DENSE), ("local", CANDIDATE_LOCAL)):
            bx = BatchedHybridExecutor(table, idx,
                                       cost_model=CostModel(force=force))
            bx.execute_batch(wl, plans)  # warm the jit caches
            reps = 3
            t0 = time.perf_counter()
            for _ in range(reps):
                bx.execute_batch(wl, plans)
            row[f"{label}_ms"] = round(
                (time.perf_counter() - t0) / reps * 1e3, 1)
        row["local_wins"] = row["local_ms"] < row["dense_ms"]
        rows.append(row)
        print(f"  overhead sweep[{precision}] B={b} scan={scan}: dense "
              f"{row['dense_ms']}ms vs local {row['local_ms']}ms -> "
              f"{'local' if row['local_wins'] else 'dense'}")
    oh = measured_overhead_rows(rows, scan=scan, n_rows=table.n_rows,
                                crossover=crossover)
    print(f"  calibrated CostModel.overhead[{precision}] ≈ {oh:.0f} "
          f"gathered rows")
    return {"n_rows": table.n_rows, "precision": precision, "table": rows,
            "overhead_rows": oh}


def measured_crossover(rows: list[dict]) -> float:
    """Largest measured work ratio at which candidate-local still wins
    (log-interpolated between the last winning and first losing sweep
    point) — the value ``serve.batch.CostModel.crossover`` should sit at."""
    wins = [r for r in rows if r["speedup"] >= 1.0]
    if not wins:
        return 0.0
    hi = max(r["work_ratio"] for r in wins)
    # losses BELOW hi are small-batch overhead artifacts, not the crossover
    losses_above = [r["work_ratio"] for r in rows
                    if r["speedup"] < 1.0 and r["work_ratio"] > hi]
    if not losses_above:
        return hi
    return round(float(np.sqrt(hi * min(losses_above))), 3)


def run(n: int = 20_000, d: int = 128, m: int = 3, k: int = 10, **_) -> dict:
    rng = np.random.default_rng(0)
    vecs = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    scal = jnp.asarray(rng.uniform(0, 10, (n, m)), jnp.float32)
    lo = jnp.asarray([3.0] + [-np.inf] * (m - 1), jnp.float32)
    hi = jnp.asarray([7.0] + [np.inf] * (m - 1), jnp.float32)
    act = jnp.asarray([True] + [False] * (m - 1))
    q = jnp.asarray(rng.normal(size=(d,)), jnp.float32)

    s_k, i_k, v_k = ops.masked_topk(q, vecs, scal, lo, hi, act, k=k)
    s_r, i_r = ref.masked_topk_ref(q, vecs, scal, lo, hi, act, n, k=k)
    parity = bool(np.allclose(np.asarray(s_k), np.asarray(s_r), atol=1e-4)
                  and np.array_equal(np.asarray(v_k), np.asarray(i_r) >= 0))

    qv, sc = ops.quantize_rows(vecs)
    s_q, i_q, _ = ops.int8_masked_topk(q, qv, sc, scal, lo, hi, act, k=k)
    rec = len(set(map(int, np.asarray(i_q))) & set(map(int, np.asarray(i_r)))) / k

    ms_ref = _timeit(lambda: ref.masked_topk_ref(q, vecs, scal, lo, hi, act,
                                                 n, k=k))
    fp32_bytes = n * d * 4
    int8_bytes = n * d * 1 + n * 4
    out = {
        "figure": "kernels_bench",
        "oracle_parity": parity,
        "int8_recall_vs_fp32": rec,
        "ref_scan_ms_cpu": round(ms_ref, 2),
        "db_bytes_fp32": fp32_bytes,
        "db_bytes_int8": int8_bytes,
        "hbm_reduction": round(fp32_bytes / int8_bytes, 2),
    }
    print(f"  kernels: parity={parity} int8_recall={rec:.2f} "
          f"HBM bytes/query {fp32_bytes/2**20:.1f}MiB -> "
          f"{int8_bytes/2**20:.1f}MiB ({out['hbm_reduction']}x)")
    out["crossover"] = crossover_sweep(n=n, d=d, m=m, k=k)
    out["measured_crossover"] = measured_crossover(out["crossover"])
    print(f"  measured crossover B·scan/n = {out['measured_crossover']}")
    return out


def calibrate_quantized(n_cross: int = 60_000, n_over: int = 500_000,
                        out: str = "benchmarks/results/quantized_crossover.json"
                        ) -> dict:
    """Per-precision CostModel calibration (``crossover`` /
    ``crossover_int8``, ``overhead`` / ``overhead_int8``): the 60k-row
    kernel crossover sweep and the 500k-row end-to-end overhead boundary,
    both precisions, written to ``benchmarks/results/``."""
    import json

    res = {"figure": "quantized_cost_model_calibration"}
    for prec in ("fp32", "int8"):
        sweep = crossover_sweep(n=n_cross, precision=prec)
        over = overhead_sweep(n=n_over, precision=prec,
                              crossover=measured_crossover(sweep))
        res[prec] = {
            "crossover_sweep": sweep,
            "measured_crossover": measured_crossover(sweep),
            "overhead_sweep": over,
            "measured_overhead_rows": over["overhead_rows"],
        }
        print(f"  [{prec}] measured crossover B·scan/n = "
              f"{res[prec]['measured_crossover']}, overhead ≈ "
              f"{over['overhead_rows']} gathered rows")
    with open(out, "w") as f:
        json.dump(res, f, indent=2)
    print(f"  wrote {out}")
    return res


if __name__ == "__main__":
    # standalone run = the calibration figures: the 60k-row crossover sweep
    # plus the 500k-row end-to-end per-batch overhead boundary the
    # CostModel defaults are measured on, at BOTH precisions (the int8
    # rows calibrate crossover_int8/overhead_int8) — written to
    # benchmarks/results/quantized_crossover.json. (benchmarks.run keeps
    # its smaller n and skips the overhead sweep — it needs the big table
    # to be meaningful.)
    calibrate_quantized()
