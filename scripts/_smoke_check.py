"""Dev scratch: quick forward/train/prefill/decode sanity over all smoke archs."""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import lm

B, S = 2, 32


def batch_for(cfg):
    rng = np.random.default_rng(0)
    b = {}
    s_tok = S
    if cfg.modality == "vlm":
        s_tok = S - cfg.n_prefix_embeds
        b["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_prefix_embeds, cfg.d_model)), jnp.float32)
    if cfg.inputs_are_embeds:
        b["embeds"] = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)), jnp.float32)
        b["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
        return b
    b["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, s_tok)), jnp.int32)
    b["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, s_tok)), jnp.int32)
    return b


def main():
    failures = []
    for arch in configs.ARCHS:
        cfg = configs.get_config(arch, smoke=True)
        try:
            params = lm.init(jax.random.PRNGKey(0), cfg)
            batch = batch_for(cfg)
            loss, metrics = jax.jit(
                lambda p, b: lm.loss_fn(p, cfg, b, remat=True))(params, batch)
            assert jnp.isfinite(loss), f"{arch}: loss not finite"
            # prefill + decode
            logits, cache = jax.jit(
                lambda p, b: lm.prefill(p, cfg, b, max_len=S + 8))(params, batch)
            assert logits.shape == (B, cfg.vocab)
            assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: prefill logits NaN"
            if cfg.inputs_are_embeds:
                inp = {"embed": jnp.zeros((B, cfg.d_model), jnp.float32)}
            else:
                inp = {"token": jnp.argmax(logits, -1).astype(jnp.int32)}
            lg2, cache = jax.jit(
                lambda p, i, c: lm.decode_step(p, cfg, i, jnp.asarray(S, jnp.int32), c)
            )(params, inp, cache)
            assert lg2.shape == (B, cfg.vocab)
            assert bool(jnp.all(jnp.isfinite(lg2))), f"{arch}: decode logits NaN"
            print(f"OK   {arch:26s} loss={float(loss):.4f}")
        except Exception as e:  # noqa: BLE001
            failures.append((arch, e))
            print(f"FAIL {arch:26s} {type(e).__name__}: {e}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
