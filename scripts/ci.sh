#!/usr/bin/env bash
# Tier-1 CI entry point: install dev deps, lint, run the test suite.
#   bash scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install -r requirements-dev.txt

# lint (config in ruff.toml); tolerate offline images without ruff
if python -m ruff --version >/dev/null 2>&1; then
  python -m ruff check .
else
  echo "ruff unavailable; skipping lint"
fi

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q
