#!/usr/bin/env bash
# Tier-1 CI entry point: install dev deps, lint, run the test suite.
#   bash scripts/ci.sh            # full tier-1 (+ coverage floor when
#                                 # pytest-cov is available)
#   CI_FAST=1 bash scripts/ci.sh  # keep-fast filter: skips @slow serving
#                                 # tests (the lint job's default)
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install -r requirements-dev.txt

# lint (config in ruff.toml); tolerate offline images without ruff
if python -m ruff --version >/dev/null 2>&1; then
  python -m ruff check .
else
  echo "ruff unavailable; skipping lint"
fi

# boomlint: trace-safety & recompile-hazard static analysis (AST +
# jaxpr/HLO; docs/analysis.md). Gates on zero unsuppressed findings beyond
# the checked-in baseline. CI_FAST keeps it AST-only; full runs also trace
# the serving kernels (level 2).
BOOMLINT_ARGS=(src/repro --baseline boomlint.baseline.json)
if [[ "${CI_FAST:-0}" == "1" ]]; then
  BOOMLINT_ARGS+=(--no-trace)
fi
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python -m repro.analysis.cli "${BOOMLINT_ARGS[@]}"

PYTEST_ARGS=(-x -q)
if [[ "${CI_FAST:-0}" == "1" ]]; then
  PYTEST_ARGS+=(-m "not slow")
fi
# coverage floor: enforced whenever pytest-cov is importable (CI installs it
# via requirements-dev.txt); offline images without it run plain so the
# baked-in toolchain stays sufficient
if python -c "import pytest_cov" >/dev/null 2>&1 && [[ "${CI_FAST:-0}" != "1" ]]; then
  PYTEST_ARGS+=(--cov=repro --cov-report=term --cov-fail-under=78)
else
  echo "pytest-cov unavailable or CI_FAST set; running without coverage floor"
fi

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest "${PYTEST_ARGS[@]}"
