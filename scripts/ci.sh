#!/usr/bin/env bash
# Tier-1 CI entry point: install dev deps, run the test suite.
#   bash scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install -r requirements-dev.txt
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q
